//! The engine's resident base-weight representation.
//!
//! A [`WeightStore`] is the *only* form in which a linear layer's base
//! matrix Ŵ lives inside [`crate::salr::SalrLayer`] and
//! [`crate::infer::EngineWeights`]: dense f32, bitmap-sparse, or
//! bitmap+NF4. In the compressed formats no persistent dense copy exists —
//! the GEMM tier decodes per tile inside its panel pack step
//! ([`crate::gemm::dense::PackB`]), so weights stream from memory at
//! compressed size and the freed RAM becomes KV blocks.
//!
//! Every construction/Drop is accounted in [`crate::util::mem`]'s
//! per-thread resident-weight counters, which is how the test suite
//! asserts that engine construction in a compressed format leaves zero
//! resident dense weight bytes behind.

use crate::quant::SparseNf4Matrix;
use crate::sparse::BitmapMatrix;
use crate::tensor::Tensor;
use crate::util::mem;

/// NF4 block size used for the bitmap+NF4 store and the `SparseNf4`
/// serialization encoding (the QLoRA default).
pub const NF4_BLOCK: usize = 64;

/// Which resident representation a base weight matrix uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightFormat {
    /// Dense f32 (pruned zeros stored explicitly).
    F32,
    /// Bitmap mask + packed f32 nonzeros (exact, ~2× smaller at p=0.5).
    Bitmap,
    /// Bitmap mask + NF4-quantized nonzeros (lossy, ~5× smaller).
    Nf4,
}

impl WeightFormat {
    /// Parse a `--weight-format` / `SALR_WEIGHT_FORMAT` token.
    pub fn parse(s: &str) -> Option<WeightFormat> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "dense" => Some(WeightFormat::F32),
            "bitmap" => Some(WeightFormat::Bitmap),
            "nf4" => Some(WeightFormat::Nf4),
            _ => None,
        }
    }

    /// The flag/env token for this format.
    pub fn name(&self) -> &'static str {
        match self {
            WeightFormat::F32 => "f32",
            WeightFormat::Bitmap => "bitmap",
            WeightFormat::Nf4 => "nf4",
        }
    }

    /// The format `SALR_WEIGHT_FORMAT` selects, defaulting to `Bitmap`
    /// (the paper's deployment form and the pre-flag behavior). CI runs
    /// the whole suite once per format through this default.
    pub fn env_default() -> WeightFormat {
        match std::env::var("SALR_WEIGHT_FORMAT") {
            Ok(s) => WeightFormat::parse(&s).unwrap_or(WeightFormat::Bitmap),
            Err(_) => WeightFormat::Bitmap,
        }
    }

    /// Whether this format holds a dense f32 copy resident.
    pub fn is_dense(&self) -> bool {
        matches!(self, WeightFormat::F32)
    }
}

#[derive(Debug, PartialEq)]
enum Repr {
    Dense(Tensor),
    Bitmap(BitmapMatrix),
    BitmapNf4(SparseNf4Matrix),
}

/// Borrowed view of a store's representation, for consumers that pick a
/// kernel per variant (small-m direct sparse GEMM, merge, stats).
pub enum WeightView<'a> {
    /// Dense f32 matrix.
    Dense(&'a Tensor),
    /// Bitmap mask + f32 nonzeros.
    Bitmap(&'a BitmapMatrix),
    /// Bitmap mask + NF4 nonzeros.
    BitmapNf4(&'a SparseNf4Matrix),
}

/// A base weight matrix in its resident (possibly compressed) form.
///
/// Construction goes through [`WeightStore::dense`] /
/// [`WeightStore::encode`] so the [`crate::util::mem`] resident-byte
/// counters always match what is actually held; `Drop` (and `Clone`)
/// keep them balanced.
#[derive(Debug)]
pub struct WeightStore {
    repr: Repr,
    /// Bytes registered with the mem counters at construction.
    tracked: i64,
}

impl WeightStore {
    fn track(repr: Repr) -> WeightStore {
        let tracked = match &repr {
            Repr::Dense(t) => {
                let b = (t.len() * 4) as i64;
                mem::track_dense_weight_bytes(b);
                b
            }
            Repr::Bitmap(bm) => {
                let b = bm.storage_bytes() as i64;
                mem::track_compressed_weight_bytes(b);
                b
            }
            Repr::BitmapNf4(snf) => {
                let b = snf.storage_bytes() as i64;
                mem::track_compressed_weight_bytes(b);
                b
            }
        };
        WeightStore { repr, tracked }
    }

    /// Hold a dense f32 matrix (the `f32` weight format).
    pub fn dense(t: Tensor) -> WeightStore {
        Self::track(Repr::Dense(t))
    }

    /// Hold an already-encoded bitmap matrix.
    pub fn from_bitmap(bm: BitmapMatrix) -> WeightStore {
        Self::track(Repr::Bitmap(bm))
    }

    /// Hold an already-encoded bitmap+NF4 matrix.
    pub fn from_sparse_nf4(snf: SparseNf4Matrix) -> WeightStore {
        Self::track(Repr::BitmapNf4(snf))
    }

    /// Encode a dense matrix into the requested resident format. `F32`
    /// keeps the values as-is; `Bitmap` is exact over the nonzeros; `Nf4`
    /// additionally NF4-quantizes them ([`NF4_BLOCK`]-wide blocks over
    /// the nonzero stream).
    pub fn encode(t: &Tensor, fmt: WeightFormat) -> WeightStore {
        match fmt {
            WeightFormat::F32 => Self::dense(t.clone()),
            WeightFormat::Bitmap => Self::from_bitmap(BitmapMatrix::encode(t)),
            WeightFormat::Nf4 => Self::from_sparse_nf4(SparseNf4Matrix::encode(t, NF4_BLOCK)),
        }
    }

    /// The resident format of this store.
    pub fn format(&self) -> WeightFormat {
        match &self.repr {
            Repr::Dense(_) => WeightFormat::F32,
            Repr::Bitmap(_) => WeightFormat::Bitmap,
            Repr::BitmapNf4(_) => WeightFormat::Nf4,
        }
    }

    /// Borrow the concrete representation.
    pub fn view(&self) -> WeightView<'_> {
        match &self.repr {
            Repr::Dense(t) => WeightView::Dense(t),
            Repr::Bitmap(bm) => WeightView::Bitmap(bm),
            Repr::BitmapNf4(snf) => WeightView::BitmapNf4(snf),
        }
    }

    pub fn rows(&self) -> usize {
        match &self.repr {
            Repr::Dense(t) => t.rows(),
            Repr::Bitmap(bm) => bm.rows(),
            Repr::BitmapNf4(snf) => snf.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match &self.repr {
            Repr::Dense(t) => t.cols(),
            Repr::Bitmap(bm) => bm.cols(),
            Repr::BitmapNf4(snf) => snf.cols(),
        }
    }

    /// Materialize the full dense matrix (reference paths and merges only
    /// — never on the serving hot path).
    pub fn decode(&self) -> Tensor {
        match &self.repr {
            Repr::Dense(t) => t.clone(),
            Repr::Bitmap(bm) => bm.decode(),
            Repr::BitmapNf4(snf) => snf.decode(),
        }
    }

    /// Decode rows `[r0, r1)` into `out` (row-major, `(r1-r0) × cols`) —
    /// the pipeline decode stage's unit of work, uniform across formats.
    pub fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]) {
        match &self.repr {
            Repr::Dense(t) => {
                let cols = t.cols();
                out[..(r1 - r0) * cols].copy_from_slice(&t.data()[r0 * cols..r1 * cols]);
            }
            Repr::Bitmap(bm) => bm.decode_rows_into(r0, r1, out),
            Repr::BitmapNf4(snf) => snf.decode_rows_into(r0, r1, out),
        }
    }

    /// Resident bytes of this representation (what the mem counters hold).
    pub fn storage_bytes(&self) -> usize {
        self.tracked as usize
    }

    /// Bytes of the equivalent dense f32 matrix.
    pub fn dense_bytes(&self) -> usize {
        self.rows() * self.cols() * 4
    }

    /// Nonzero count (dense stores count exact nonzeros).
    pub fn nnz(&self) -> usize {
        match &self.repr {
            Repr::Dense(t) => t.nnz(),
            Repr::Bitmap(bm) => bm.nnz(),
            Repr::BitmapNf4(snf) => snf.nnz(),
        }
    }
}

impl Clone for WeightStore {
    fn clone(&self) -> WeightStore {
        // Re-register through the constructors so counters stay balanced.
        let repr = match &self.repr {
            Repr::Dense(t) => Repr::Dense(t.clone()),
            Repr::Bitmap(bm) => Repr::Bitmap(bm.clone()),
            Repr::BitmapNf4(snf) => Repr::BitmapNf4(snf.clone()),
        };
        Self::track(repr)
    }
}

impl PartialEq for WeightStore {
    fn eq(&self, other: &WeightStore) -> bool {
        self.repr == other.repr
    }
}

impl Drop for WeightStore {
    fn drop(&mut self) {
        match &self.repr {
            Repr::Dense(_) => mem::track_dense_weight_bytes(-self.tracked),
            Repr::Bitmap(_) | Repr::BitmapNf4(_) => {
                mem::track_compressed_weight_bytes(-self.tracked)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::util::rng::Rng;

    fn sparse_tensor(seed: u64, r: usize, c: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::randn(&[r, c], 1.0, &mut rng);
        prune_global(&mut [&mut t], 0.5);
        t
    }

    #[test]
    fn formats_parse_and_roundtrip_names() {
        for fmt in [WeightFormat::F32, WeightFormat::Bitmap, WeightFormat::Nf4] {
            assert_eq!(WeightFormat::parse(fmt.name()), Some(fmt));
        }
        assert_eq!(WeightFormat::parse("dense"), Some(WeightFormat::F32));
        assert_eq!(WeightFormat::parse("NF4"), Some(WeightFormat::Nf4));
        assert_eq!(WeightFormat::parse("nope"), None);
    }

    #[test]
    fn dense_and_bitmap_decode_exactly() {
        let t = sparse_tensor(900, 13, 37);
        for fmt in [WeightFormat::F32, WeightFormat::Bitmap] {
            let s = WeightStore::encode(&t, fmt);
            assert_eq!(s.rows(), 13);
            assert_eq!(s.cols(), 37);
            assert_eq!(s.decode(), t, "{:?}", fmt);
            assert_eq!(s.nnz(), t.nnz());
        }
    }

    #[test]
    fn nf4_decode_matches_matrix_decode() {
        let t = sparse_tensor(901, 9, 70);
        let s = WeightStore::encode(&t, WeightFormat::Nf4);
        let oracle = SparseNf4Matrix::encode(&t, NF4_BLOCK).decode();
        assert_eq!(s.decode(), oracle);
    }

    #[test]
    fn decode_rows_matches_full_decode_across_formats() {
        let t = sparse_tensor(902, 16, 41);
        for fmt in [WeightFormat::F32, WeightFormat::Bitmap, WeightFormat::Nf4] {
            let s = WeightStore::encode(&t, fmt);
            let full = s.decode();
            let mut buf = vec![f32::NAN; 5 * 41];
            s.decode_rows_into(3, 8, &mut buf);
            for k in 0..5 {
                assert_eq!(&buf[k * 41..(k + 1) * 41], full.row(3 + k), "{:?}", fmt);
            }
        }
    }

    #[test]
    fn mem_counters_balance_over_lifecycle() {
        let d0 = mem::dense_weight_bytes();
        let c0 = mem::compressed_weight_bytes();
        let t = sparse_tensor(903, 32, 64);
        {
            let dense = WeightStore::encode(&t, WeightFormat::F32);
            assert_eq!(mem::dense_weight_bytes() - d0, dense.storage_bytes() as i64);
            assert_eq!(mem::compressed_weight_bytes(), c0);
            let bm = WeightStore::encode(&t, WeightFormat::Bitmap);
            let nf = WeightStore::encode(&t, WeightFormat::Nf4);
            assert_eq!(
                mem::compressed_weight_bytes() - c0,
                (bm.storage_bytes() + nf.storage_bytes()) as i64
            );
            // Clones register too…
            let extra = bm.clone();
            assert_eq!(
                mem::compressed_weight_bytes() - c0,
                (bm.storage_bytes() + nf.storage_bytes() + extra.storage_bytes()) as i64
            );
        }
        // …and everything unregisters on drop.
        assert_eq!(mem::dense_weight_bytes(), d0);
        assert_eq!(mem::compressed_weight_bytes(), c0);
    }

    #[test]
    fn compressed_formats_are_smaller_than_dense() {
        let t = sparse_tensor(904, 64, 128);
        let dense = WeightStore::encode(&t, WeightFormat::F32);
        let bm = WeightStore::encode(&t, WeightFormat::Bitmap);
        let nf = WeightStore::encode(&t, WeightFormat::Nf4);
        assert!(bm.storage_bytes() < dense.storage_bytes());
        assert!(nf.storage_bytes() < bm.storage_bytes());
        assert_eq!(dense.storage_bytes(), dense.dense_bytes());
    }
}
