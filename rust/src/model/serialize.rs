//! Byte-exact model serialization with per-tensor encodings.
//!
//! This is where "true model compression" (the paper's core deployment
//! claim) is measured: a serialized SALR checkpoint stores pruned base
//! weights as bitmap + values, QSALR additionally NF4-quantizes the kept
//! values, and the file size IS the model size reported in Fig. 1 and
//! Tables 3/6.
//!
//! Format (little-endian):
//!   magic "SALRMODL" | u32 version | u32 tensor_count
//!   per tensor: u16 name_len | name | u8 encoding | u32 payload_len | payload

use super::params::ParamStore;
use super::store::{WeightStore, NF4_BLOCK};
use crate::quant::{Nf4Matrix, SparseNf4Matrix};
use crate::sparse::BitmapMatrix;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SALRMODL";
const VERSION: u32 = 1;

/// Per-tensor storage encoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    /// Raw f32 (shape header + data).
    Dense = 0,
    /// Bitmap + f32 values (the paper's sparse deployment format).
    Bitmap = 1,
    /// NF4-quantized dense (4 bits/elem + blockwise scales).
    Nf4 = 2,
    /// Bitmap mask + NF4-quantized kept values (QSALR, Table 6).
    SparseNf4 = 3,
}

/// A tensor with its chosen encoding.
pub struct TensorRecord {
    pub name: String,
    pub encoding: Encoding,
    pub payload: Vec<u8>,
}

/// An encoded model file in memory.
pub struct ModelFile {
    pub records: Vec<TensorRecord>,
}

impl ModelFile {
    /// Total serialized size in bytes.
    pub fn total_bytes(&self) -> usize {
        16 + self
            .records
            .iter()
            .map(|r| 2 + r.name.len() + 1 + 4 + r.payload.len())
            .sum::<usize>()
    }
}

fn encode_dense(t: &Tensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + t.len() * 4 + 4 * t.ndim());
    out.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
    for &d in t.shape() {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in t.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn decode_dense(bytes: &[u8]) -> Result<Tensor> {
    ensure!(bytes.len() >= 4, "dense: truncated");
    let ndim = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
    let mut shape = Vec::with_capacity(ndim);
    let mut p = 4;
    for _ in 0..ndim {
        shape.push(u32::from_le_bytes(bytes[p..p + 4].try_into()?) as usize);
        p += 4;
    }
    let n: usize = shape.iter().product();
    ensure!(bytes.len() == p + n * 4, "dense: bad payload");
    let mut data = Vec::with_capacity(n);
    for _ in 0..n {
        data.push(f32::from_le_bytes(bytes[p..p + 4].try_into()?));
        p += 4;
    }
    Ok(Tensor::from_vec(&shape, data))
}

fn encode_sparse_nf4(t: &Tensor) -> Vec<u8> {
    // Bitmap *pattern* (1 bit/elem) + NF4 codes of the kept values only
    // (4.5 bits/nnz): the QSALR format of Table 6. The byte layout is the
    // runtime store's own — the serialized payload IS the resident
    // representation, so loading it never densifies.
    SparseNf4Matrix::encode(t, NF4_BLOCK).to_bytes()
}

fn decode_sparse_nf4(bytes: &[u8]) -> Result<Tensor> {
    Ok(SparseNf4Matrix::from_bytes(bytes)?.decode())
}

/// Choose + apply an encoding for one tensor.
pub fn encode_tensor(name: &str, t: &Tensor, enc: Encoding) -> Result<TensorRecord> {
    let payload = match enc {
        Encoding::Dense => encode_dense(t),
        Encoding::Bitmap => {
            ensure!(t.ndim() == 2, "bitmap encoding needs 2-D tensor ({name})");
            BitmapMatrix::encode(t).to_bytes()
        }
        Encoding::Nf4 => {
            ensure!(t.ndim() == 2, "nf4 encoding needs 2-D tensor ({name})");
            Nf4Matrix::quantize(t, NF4_BLOCK).to_bytes()
        }
        Encoding::SparseNf4 => {
            ensure!(t.ndim() == 2, "sparse-nf4 needs 2-D tensor ({name})");
            encode_sparse_nf4(t)
        }
    };
    Ok(TensorRecord {
        name: name.to_string(),
        encoding: enc,
        payload,
    })
}

/// Decode a record back to a dense tensor (lossy for Nf4 encodings).
pub fn decode_tensor(rec: &TensorRecord) -> Result<Tensor> {
    match rec.encoding {
        Encoding::Dense => decode_dense(&rec.payload),
        Encoding::Bitmap => Ok(BitmapMatrix::from_bytes(&rec.payload)?.decode()),
        Encoding::Nf4 => Ok(Nf4Matrix::from_bytes(&rec.payload)?.dequantize()),
        Encoding::SparseNf4 => decode_sparse_nf4(&rec.payload),
    }
}

/// Serialize a parameter store. `encoding_for` picks the per-tensor
/// encoding (e.g. bitmap for pruned base weights, dense for norms).
pub fn save_model(
    path: impl AsRef<Path>,
    params: &ParamStore,
    mut encoding_for: impl FnMut(&str, &Tensor) -> Encoding,
) -> Result<u64> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (name, t) in params.iter() {
        let enc = encoding_for(name, t);
        let rec = encode_tensor(name, t, enc)?;
        buf.extend_from_slice(&(rec.name.len() as u16).to_le_bytes());
        buf.extend_from_slice(rec.name.as_bytes());
        buf.push(rec.encoding as u8);
        buf.extend_from_slice(&(rec.payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&rec.payload);
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(&buf)?;
    Ok(buf.len() as u64)
}

/// Parse a serialized model file into its per-tensor records.
fn read_file_records(path: impl AsRef<Path>) -> Result<Vec<TensorRecord>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?
        .read_to_end(&mut bytes)?;
    ensure!(bytes.len() >= 16 && &bytes[..8] == MAGIC, "bad model file");
    let version = u32::from_le_bytes(bytes[8..12].try_into()?);
    ensure!(version == VERSION, "unsupported model version {version}");
    let count = u32::from_le_bytes(bytes[12..16].try_into()?) as usize;
    let mut p = 16usize;
    let mut records = Vec::with_capacity(count);
    for _ in 0..count {
        ensure!(bytes.len() >= p + 2, "truncated record header");
        let nlen = u16::from_le_bytes(bytes[p..p + 2].try_into()?) as usize;
        p += 2;
        ensure!(bytes.len() >= p + nlen + 5, "truncated record header");
        let name = std::str::from_utf8(&bytes[p..p + nlen])?.to_string();
        p += nlen;
        let enc = match bytes[p] {
            0 => Encoding::Dense,
            1 => Encoding::Bitmap,
            2 => Encoding::Nf4,
            3 => Encoding::SparseNf4,
            e => bail!("unknown encoding {e}"),
        };
        p += 1;
        let plen = u32::from_le_bytes(bytes[p..p + 4].try_into()?) as usize;
        p += 4;
        ensure!(bytes.len() >= p + plen, "truncated record payload");
        records.push(TensorRecord {
            name,
            encoding: enc,
            payload: bytes[p..p + plen].to_vec(),
        });
        p += plen;
    }
    Ok(records)
}

/// Load a serialized model (all tensors decoded to dense).
pub fn load_model(path: impl AsRef<Path>) -> Result<ParamStore> {
    let mut store = ParamStore::new();
    for rec in read_file_records(path)? {
        store.insert(&rec.name, decode_tensor(&rec)?);
    }
    Ok(store)
}

/// Decode a record into its **resident** form: compressed encodings stay
/// compressed (the serialized payload of `Bitmap`/`SparseNf4` is already
/// the runtime [`WeightStore`] representation — no dense f32 copy is ever
/// materialized on this path; `Dense`/`Nf4` records decode to a dense
/// store).
pub fn decode_tensor_store(rec: &TensorRecord) -> Result<WeightStore> {
    Ok(match rec.encoding {
        Encoding::Dense => WeightStore::dense(decode_dense(&rec.payload)?),
        Encoding::Bitmap => WeightStore::from_bitmap(BitmapMatrix::from_bytes(&rec.payload)?),
        Encoding::Nf4 => WeightStore::dense(Nf4Matrix::from_bytes(&rec.payload)?.dequantize()),
        Encoding::SparseNf4 => {
            WeightStore::from_sparse_nf4(SparseNf4Matrix::from_bytes(&rec.payload)?)
        }
    })
}

/// Load a serialized model **without densifying** compressed tensors:
/// every record becomes a [`WeightStore`] in its serialized
/// representation, ready to hand to the compressed-weight GEMM tiers.
pub fn load_stores(path: impl AsRef<Path>) -> Result<Vec<(String, WeightStore)>> {
    read_file_records(path)?
        .iter()
        .map(|rec| Ok((rec.name.clone(), decode_tensor_store(rec)?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::util::rng::Rng;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("salr_model_test_{tag}_{}", std::process::id()))
    }

    #[test]
    fn dense_roundtrip_exact() {
        let mut rng = Rng::new(200);
        let mut p = ParamStore::new();
        p.insert("a", Tensor::randn(&[8, 6], 1.0, &mut rng));
        p.insert("norm", Tensor::full(&[6], 1.0));
        let path = tmpfile("dense");
        save_model(&path, &p, |_, _| Encoding::Dense).unwrap();
        let back = load_model(&path).unwrap();
        assert_eq!(back.get("a").unwrap(), p.get("a").unwrap());
        assert_eq!(back.get("norm").unwrap(), p.get("norm").unwrap());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn bitmap_roundtrip_exact_and_smaller() {
        let mut rng = Rng::new(201);
        let mut w = Tensor::randn(&[128, 128], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let mut p = ParamStore::new();
        p.insert("w", w.clone());
        let path_d = tmpfile("bm_dense");
        let path_b = tmpfile("bm_bitmap");
        let size_dense = save_model(&path_d, &p, |_, _| Encoding::Dense).unwrap();
        let size_bitmap = save_model(&path_b, &p, |_, _| Encoding::Bitmap).unwrap();
        assert!(size_bitmap * 17 < size_dense * 10, "{size_bitmap} vs {size_dense}");
        let back = load_model(&path_b).unwrap();
        assert_eq!(back.get("w").unwrap(), &w);
        std::fs::remove_file(path_d).unwrap();
        std::fs::remove_file(path_b).unwrap();
    }

    #[test]
    fn nf4_roundtrip_lossy_but_close() {
        let mut rng = Rng::new(202);
        let w = Tensor::randn(&[64, 64], 0.05, &mut rng);
        let mut p = ParamStore::new();
        p.insert("w", w.clone());
        let path = tmpfile("nf4");
        let size = save_model(&path, &p, |_, _| Encoding::Nf4).unwrap();
        assert!(size < (64 * 64 * 4) as u64 / 6, "nf4 should be ~7x smaller");
        let back = load_model(&path).unwrap();
        let rel = crate::tensor::sub(back.get("w").unwrap(), &w).fro_norm() / w.fro_norm();
        assert!(rel < 0.12, "rel={rel}");
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn sparse_nf4_preserves_pattern() {
        let mut rng = Rng::new(203);
        let mut w = Tensor::randn(&[96, 64], 0.05, &mut rng);
        prune_global(&mut [&mut w], 0.2);
        let mut p = ParamStore::new();
        p.insert("w", w.clone());
        let path = tmpfile("snf4");
        save_model(&path, &p, |_, _| Encoding::SparseNf4).unwrap();
        let back = load_model(&path).unwrap();
        let got = back.get("w").unwrap();
        // Pruned positions stay exactly zero; kept values are NF4-lossy
        // (and may themselves round to the codebook's zero).
        for (a, b) in w.data().iter().zip(got.data()) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
        let rel = crate::tensor::sub(got, &w).fro_norm() / w.fro_norm();
        assert!(rel < 0.15, "rel={rel}");
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn mixed_encoding_size_accounting() {
        // QSALR-style: big matrices sparse-NF4, the rest dense — total file
        // size must land near the analytic estimate.
        let mut rng = Rng::new(204);
        let mut p = ParamStore::new();
        let mut w = Tensor::randn(&[256, 256], 0.05, &mut rng);
        prune_global(&mut [&mut w], 0.2);
        p.insert("layer0.wq", w);
        p.insert("norm", Tensor::full(&[256], 1.0));
        let path = tmpfile("mixed");
        let size = save_model(&path, &p, |name, _| {
            if name.contains("wq") {
                Encoding::SparseNf4
            } else {
                Encoding::Dense
            }
        })
        .unwrap();
        // 256·256 · (1 bit map + 0.8 · 4.5 bits values) / 8 ≈ 38 KB + dense norm.
        assert!(size > 30_000 && size < 60_000, "size={size}");
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn sparse_nf4_payload_is_the_runtime_store_representation() {
        // The serialized SparseNf4 payload must be byte-identical to the
        // runtime store's own to_bytes(), and decoding the record must be
        // byte-identical to quantize-then-dequantize through the runtime
        // store — the file format and the resident format are one.
        let mut rng = Rng::new(205);
        let mut w = Tensor::randn(&[60, 41], 0.05, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let rec = encode_tensor("w", &w, Encoding::SparseNf4).unwrap();
        let store = SparseNf4Matrix::encode(&w, NF4_BLOCK);
        assert_eq!(rec.payload, store.to_bytes());
        let via_record = decode_tensor(&rec).unwrap();
        let via_store = store.decode();
        assert_eq!(via_record, via_store);
    }

    #[test]
    fn load_stores_keeps_compressed_tensors_compressed() {
        // Round-trip through the store-level loader: compressed records
        // come back in their compressed resident form (no dense f32 copy
        // registered), and decoding them matches the dense loader exactly.
        let mut rng = Rng::new(206);
        let mut w = Tensor::randn(&[80, 64], 0.05, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let mut p = ParamStore::new();
        p.insert("layer0.wq", w.clone());
        p.insert("layer0.wk", w.clone());
        p.insert("norm", Tensor::full(&[64], 1.0));
        let path = tmpfile("stores");
        save_model(&path, &p, |name, _| match name {
            "layer0.wq" => Encoding::Bitmap,
            "layer0.wk" => Encoding::SparseNf4,
            _ => Encoding::Dense,
        })
        .unwrap();
        let dense0 = crate::util::mem::dense_weight_bytes();
        let stores = load_stores(&path).unwrap();
        let by_name: std::collections::HashMap<_, _> =
            stores.iter().map(|(n, s)| (n.as_str(), s)).collect();
        assert_eq!(
            by_name["layer0.wq"].format(),
            crate::model::WeightFormat::Bitmap
        );
        assert_eq!(
            by_name["layer0.wk"].format(),
            crate::model::WeightFormat::Nf4
        );
        assert!(by_name["norm"].format().is_dense());
        // Only the dense norm registered resident dense bytes.
        assert_eq!(
            crate::util::mem::dense_weight_bytes() - dense0,
            64 * 4,
            "compressed records must not materialize dense weights on load"
        );
        let dense_load = load_model(&path).unwrap();
        for (name, store) in &stores {
            assert_eq!(&store.decode(), dense_load.get(name).unwrap(), "{name}");
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn sparse_nf4_roundtrip_error_is_blockwise_bounded() {
        // Worst-case error bound for the quantize→serialize→load
        // round-trip: within each 64-value stream block the absolute
        // error of a kept value is at most scale × (half the widest
        // codebook gap), and pruned positions are exactly zero.
        let mut rng = Rng::new(207);
        let mut w = Tensor::randn(&[48, 80], 0.05, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let rec = encode_tensor("w", &w, Encoding::SparseNf4).unwrap();
        let back = decode_tensor(&rec).unwrap();
        let codebook = crate::quant::NF4_CODEBOOK;
        let max_gap = codebook
            .windows(2)
            .map(|p| p[1] - p[0])
            .fold(0.0f32, f32::max);
        // Recompute the per-block scales the encoder used.
        let kept: Vec<f32> = w.data().iter().copied().filter(|v| *v != 0.0).collect();
        let mut kept_idx = 0usize;
        for (a, b) in w.data().iter().zip(back.data()) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0, "pruned position must stay exactly zero");
                continue;
            }
            let block = &kept[(kept_idx / NF4_BLOCK) * NF4_BLOCK
                ..((kept_idx / NF4_BLOCK) * NF4_BLOCK + NF4_BLOCK).min(kept.len())];
            let absmax = block.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let scale = if absmax == 0.0 { 1.0 } else { absmax };
            let bound = scale * max_gap / 2.0 + 1e-6;
            assert!(
                (a - b).abs() <= bound,
                "kept value error {} exceeds blockwise bound {bound}",
                (a - b).abs()
            );
            kept_idx += 1;
        }
    }
}
