//! Model state owned by the coordinator: a named parameter store whose
//! canonical (sorted-key) order matches the jax pytree flattening in the
//! AOT artifacts, plus byte-exact compressed serialization — the "model
//! size" numbers of Fig. 1 / Tables 3 & 6 come from [`serialize`].

mod params;
mod serialize;
mod store;

pub use params::ParamStore;
pub use serialize::{
    decode_tensor_store, load_model, load_stores, save_model, Encoding, ModelFile, TensorRecord,
};
pub use store::{WeightFormat, WeightStore, WeightView, NF4_BLOCK};
