//! Model state owned by the coordinator: a named parameter store whose
//! canonical (sorted-key) order matches the jax pytree flattening in the
//! AOT artifacts, plus byte-exact compressed serialization — the "model
//! size" numbers of Fig. 1 / Tables 3 & 6 come from [`serialize`].

mod params;
mod serialize;

pub use params::ParamStore;
pub use serialize::{load_model, save_model, Encoding, ModelFile, TensorRecord};
