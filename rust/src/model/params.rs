//! Named parameter store with jax-compatible canonical ordering.

use crate::runtime::ModelCfg;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// A sorted name → tensor map. Iteration order (BTreeMap) equals the
/// sorted-key order jax uses when flattening dict pytrees, which is the
/// flat input order of every AOT artifact.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> ParamStore {
        ParamStore::default()
    }

    /// Initialize dense base parameters with the same shapes (and init
    /// scales) as python's `init_base_params`.
    pub fn init_base(cfg: &ModelCfg, rng: &mut Rng) -> ParamStore {
        let mut p = ParamStore::new();
        p.insert(
            "embed",
            Tensor::randn(&[cfg.vocab_size, cfg.d_model], 0.02, rng),
        );
        p.insert(
            "pos_embed",
            Tensor::randn(&[cfg.max_seq_len, cfg.d_model], 0.02, rng),
        );
        p.insert(
            "lm_head",
            Tensor::randn(&[cfg.d_model, cfg.vocab_size], 0.02, rng),
        );
        p.insert("final_norm", Tensor::full(&[cfg.d_model], 1.0));
        for i in 0..cfg.n_layers {
            p.insert(
                &format!("layer{i}.attn_norm"),
                Tensor::full(&[cfg.d_model], 1.0),
            );
            p.insert(
                &format!("layer{i}.mlp_norm"),
                Tensor::full(&[cfg.d_model], 1.0),
            );
            for lin in ["wq", "wk", "wv", "wo", "w_in", "w_out"] {
                let (d_in, d_out) = cfg.linear_shape(lin);
                let scale = (d_in as f32).powf(-0.5);
                p.insert(
                    &format!("layer{i}.{lin}"),
                    Tensor::randn(&[d_in, d_out], scale, rng),
                );
            }
        }
        p
    }

    /// Initialize LoRA (+ optional residual) adapters: A ~ N(0, 1/√d_in),
    /// B = 0 (standard LoRA init — adapters start as the identity).
    pub fn init_adapters(cfg: &ModelCfg, rng: &mut Rng, with_residual: bool) -> ParamStore {
        let mut p = ParamStore::new();
        for name in cfg.adapted_layers() {
            let lin = name.split('.').nth(1).unwrap();
            let (d_in, d_out) = cfg.linear_shape(lin);
            let scale = (d_in as f32).powf(-0.5);
            p.insert(
                &format!("{name}.lora_a"),
                Tensor::randn(&[d_in, cfg.rank], scale, rng),
            );
            p.insert(&format!("{name}.lora_b"), Tensor::zeros(&[cfg.rank, d_out]));
            if with_residual {
                p.insert(
                    &format!("{name}.res_a"),
                    Tensor::zeros(&[d_in, cfg.residual_rank]),
                );
                p.insert(
                    &format!("{name}.res_b"),
                    Tensor::zeros(&[cfg.residual_rank, d_out]),
                );
            }
        }
        p
    }

    /// All-ones LoSA masks (refreshed dynamically by the trainer).
    pub fn init_masks(cfg: &ModelCfg) -> ParamStore {
        let mut p = ParamStore::new();
        for name in cfg.adapted_layers() {
            let lin = name.split('.').nth(1).unwrap();
            let (d_in, d_out) = cfg.linear_shape(lin);
            p.insert(&format!("{name}.mask"), Tensor::full(&[d_in, d_out], 1.0));
        }
        p
    }

    /// Zero tensors with the same shapes (optimizer state).
    pub fn zeros_like(&self) -> ParamStore {
        let mut p = ParamStore::new();
        for (k, v) in &self.map {
            p.map.insert(k.clone(), Tensor::zeros(v.shape()));
        }
        p
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.map.remove(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Sorted names (the canonical flat order).
    pub fn names(&self) -> Vec<&str> {
        self.map.keys().map(|s| s.as_str()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&String, &mut Tensor)> {
        self.map.iter_mut()
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Dense f32 byte size.
    pub fn dense_bytes(&self) -> usize {
        self.param_count() * 4
    }

    /// Merge another store (consumes it; keys must not collide).
    pub fn absorb(&mut self, other: ParamStore) {
        for (k, v) in other.map {
            let prev = self.map.insert(k.clone(), v);
            assert!(prev.is_none(), "duplicate param {k}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 16,
            rank: 4,
            lora_alpha: 16.0,
            residual_rank: 8,
            batch_size: 2,
            ctx_keep: 0.5,
        }
    }

    #[test]
    fn base_param_count_matches_formula() {
        let cfg = test_cfg();
        let mut rng = Rng::new(1);
        let p = ParamStore::init_base(&cfg, &mut rng);
        // Mirror python's ModelConfig.param_count().
        let want = 2 * cfg.vocab_size * cfg.d_model
            + cfg.max_seq_len * cfg.d_model
            + cfg.n_layers
                * (4 * cfg.d_model * cfg.d_model
                    + 2 * cfg.d_model * cfg.d_ff
                    + 2 * cfg.d_model)
            + cfg.d_model;
        assert_eq!(p.param_count(), want);
    }

    #[test]
    fn names_are_sorted() {
        let cfg = test_cfg();
        let mut rng = Rng::new(2);
        let p = ParamStore::init_base(&cfg, &mut rng);
        let names: Vec<_> = p.names();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn adapters_shapes_and_identity_init() {
        let cfg = test_cfg();
        let mut rng = Rng::new(3);
        let a = ParamStore::init_adapters(&cfg, &mut rng, true);
        assert_eq!(a.len(), 12 * 4);
        let b = a.get("layer0.wq.lora_b").unwrap();
        assert_eq!(b.shape(), &[4, 32]);
        assert_eq!(b.nnz(), 0, "B must start at zero");
        let ra = a.get("layer1.w_out.res_a").unwrap();
        assert_eq!(ra.shape(), &[64, 8]);
        let lora_only = ParamStore::init_adapters(&cfg, &mut rng, false);
        assert_eq!(lora_only.len(), 12 * 2);
    }

    #[test]
    fn zeros_like_preserves_shapes() {
        let cfg = test_cfg();
        let mut rng = Rng::new(4);
        let p = ParamStore::init_base(&cfg, &mut rng);
        let z = p.zeros_like();
        assert_eq!(z.param_count(), p.param_count());
        for (k, v) in z.iter() {
            assert_eq!(v.nnz(), 0, "{k}");
        }
    }
}
