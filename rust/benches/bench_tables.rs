//! End-to-end serving bench in the shape of the paper's Table 4: batched
//! decode throughput (tokens/s) of the native engine under each weight
//! format/backend, on a freshly initialized model (accuracy columns come
//! from `salr exp table4`, which uses the fine-tuned checkpoints).

use salr::infer::{Backend, Engine, EngineWeights};
use salr::model::ParamStore;
use salr::prune::NmPattern;
use salr::runtime::ModelCfg;
use salr::salr::build_salr;
use salr::util::bench::Bench;
use salr::util::rng::Rng;
use std::time::Instant;

fn bench_cfg() -> ModelCfg {
    ModelCfg {
        name: "bench".into(),
        vocab_size: 256,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 1024,
        max_seq_len: 128,
        rank: 16,
        lora_alpha: 32.0,
        residual_rank: 32,
        batch_size: 8,
        ctx_keep: 0.5,
    }
}

fn tps(engine: &Engine, batch: usize, new_tokens: usize) -> f64 {
    let cfg = &engine.weights.cfg;
    let prompt_len = 32usize;
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|i| (0..prompt_len).map(|j| ((i * 31 + j * 7) % 200 + 32) as i32).collect())
        .collect();
    let _ = engine.generate_batch(&prompts, 2); // warmup
    let t0 = Instant::now();
    let _ = engine.generate_batch(&prompts, new_tokens);
    let _ = cfg;
    (batch * new_tokens) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let cfg = bench_cfg();
    let mut rng = Rng::new(5);
    let base = ParamStore::init_base(&cfg, &mut rng);
    let build = build_salr(&cfg, &base, 0.5, 9);
    let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
    for (k, v) in build.residual_adapters.iter() {
        adapters.insert(k, v.clone());
    }

    println!(
        "# Table-4-shaped serving bench: {} params, batch={}, 24 new tokens\n",
        base.param_count(),
        cfg.batch_size
    );
    let mut rows: Vec<(String, f64, usize)> = Vec::new();

    let dense = Engine::new(
        EngineWeights::dense_merged(&cfg, &base, Some(&adapters)),
        Backend::Dense,
    );
    rows.push((
        "LoRA dense".into(),
        tps(&dense, cfg.batch_size, 24),
        dense.weights.linear_storage_bytes(),
    ));

    let seq = Engine::new(
        EngineWeights::salr(&cfg, &build.params, &adapters, None),
        Backend::BitmapSequential,
    );
    rows.push((
        "SALR 50% bitmap (sequential)".into(),
        tps(&seq, cfg.batch_size, 24),
        seq.weights.linear_storage_bytes(),
    ));

    let pipe = Engine::new(
        EngineWeights::salr(&cfg, &build.params, &adapters, None),
        Backend::BitmapPipelined(Default::default()),
    );
    rows.push((
        "SALR 50% bitmap (pipelined)".into(),
        tps(&pipe, cfg.batch_size, 24),
        pipe.weights.linear_storage_bytes(),
    ));

    let nm = Engine::new(
        EngineWeights::salr(&cfg, &build.params, &adapters, Some(NmPattern::TWO_FOUR)),
        Backend::BitmapPipelined(Default::default()),
    );
    rows.push((
        "SALR 2:4 (pipelined)".into(),
        tps(&nm, cfg.batch_size, 24),
        nm.weights.linear_storage_bytes(),
    ));

    let base_tps = rows[0].1;
    println!(
        "{:<34} {:>12} {:>9} {:>14}",
        "configuration", "tokens/s", "speedup", "linear bytes"
    );
    for (name, t, bytes) in &rows {
        println!(
            "{:<34} {:>12.1} {:>8.2}x {:>14}",
            name,
            t,
            t / base_tps,
            salr::util::human_bytes(*bytes as u64)
        );
    }
    println!("\npaper shape: sparse pipelined ≥ sequential; ~2x smaller linears.");

    // Batching sweep (the batcher's operating curve).
    println!("\n# batch-size sweep (pipelined SALR)\n");
    let mut b = Bench::quick();
    let _ = &mut b;
    for &bs in &[1usize, 2, 4, 8, 16] {
        let t = tps(&pipe, bs, 8);
        println!("batch {bs:>2}: {t:>8.1} tokens/s");
    }
}
