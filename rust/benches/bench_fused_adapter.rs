//! Adapter concatenation ablation (paper, "Concatenating Multi-LoRA
//! adapters"): n separate rank-r GEMM pairs vs one fused rank-(n·r) pair.

use salr::gemm::fused::AdapterStack;
use salr::tensor::Tensor;
use salr::util::bench::{black_box, Bench};
use salr::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let (k, n, m) = (1024usize, 1024usize, 8usize);
    println!("# fused vs sequential adapters (k={k}, n={n}, batch={m})\n");
    for &(count, r) in &[(2usize, 16usize), (4, 16), (8, 8), (2, 64)] {
        let adapters: Vec<(Tensor, Tensor)> = (0..count)
            .map(|_| {
                (
                    Tensor::randn(&[k, r], 0.1, &mut rng),
                    Tensor::randn(&[r, n], 0.1, &mut rng),
                )
            })
            .collect();
        let refs: Vec<(&Tensor, &Tensor)> = adapters.iter().map(|(a, b)| (a, b)).collect();
        let stack = AdapterStack::concat(&refs);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut out = vec![0.0f32; m * n];
        let mut b = Bench::new();
        let work = stack.flops(m);
        b.run_with_work(
            &format!("sequential {count}x rank-{r}"),
            work,
            &mut || {
                stack.apply_sequential(x.data(), m, &mut out);
                black_box(&out);
            },
        );
        b.run_with_work(
            &format!("fused      {count}x rank-{r} (rank {})", count * r),
            work,
            &mut || {
                stack.apply_fused(x.data(), m, &mut out);
                black_box(&out);
            },
        );
        println!(
            "{}",
            b.comparison_table(&format!("{count} adapters of rank {r}"))
        );
    }
}
