//! Sparse-format microbench: bitmap vs CSR decode throughput and storage
//! (the paper's "CSR incurs significant indexing overhead" claim), plus
//! byte-LUT vs branchy bit-iteration decode variants.

use salr::prune::prune_global;
use salr::sparse::{BitmapMatrix, CsrMatrix};
use salr::tensor::Tensor;
use salr::util::bench::{black_box, Bench};
use salr::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let (k, n) = (1024usize, 1024usize);
    println!("# bitmap vs CSR — decode {k}x{n} @ varying sparsity\n");
    for &p in &[0.5f64, 0.7, 0.9] {
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        prune_global(&mut [&mut w], p);
        let bm = BitmapMatrix::encode(&w);
        let csr = CsrMatrix::encode(&w);
        println!(
            "sparsity {:.0}%: bitmap {} vs csr {} ({} nnz)",
            p * 100.0,
            salr::util::human_bytes(bm.storage_bytes() as u64),
            salr::util::human_bytes(csr.storage_bytes() as u64),
            bm.nnz()
        );
        let mut b = Bench::new();
        let bytes = (k * n * 4) as f64;
        let mut out = vec![0.0f32; k * n];
        b.run_with_work(&format!("bitmap decode p={p}"), bytes, &mut || {
            bm.decode_rows_into(0, k, &mut out);
            black_box(&out);
        });
        b.run_with_work(&format!("csr decode p={p}"), bytes, &mut || {
            for i in 0..k {
                csr.decode_row_into(i, &mut out[i * n..(i + 1) * n]);
            }
            black_box(&out);
        });
        println!("{}", b.comparison_table(&format!("decode @{:.0}%", p * 100.0)));
    }

    // Byte-level decode variants (the inner loop of the decode stage).
    println!("# byte-decode variants (LUT vs branchy), 1M byte-blocks\n");
    let masks: Vec<u8> = (0..1_000_000).map(|_| rng.next_u64() as u8).collect();
    let values = vec![1.5f32; 8];
    let mut out = [0.0f32; 8];
    let mut b = Bench::new();
    b.run("decode_byte (LUT)", || {
        for &m in masks.iter().take(4096) {
            black_box(salr::sparse::decode_byte(m, &values, &mut out));
        }
    });
    b.run("decode_byte_bits (branchy)", || {
        for &m in masks.iter().take(4096) {
            black_box(salr::sparse::lut::decode_byte_bits(m, &values, &mut out));
        }
    });
    println!("{}", b.comparison_table("byte decode"));

    // Serialization roundtrip.
    let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
    prune_global(&mut [&mut w], 0.5);
    let bm = BitmapMatrix::encode(&w);
    let mut b = Bench::new();
    b.run("bitmap serialize", || {
        black_box(bm.to_bytes());
    });
    let bytes = bm.to_bytes();
    b.run("bitmap deserialize", || {
        black_box(BitmapMatrix::from_bytes(&bytes).unwrap());
    });
    println!("{}", b.comparison_table("serialization"));
}
