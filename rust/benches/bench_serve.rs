//! Serving-layer throughput: continuous batching at 1/2/4 engine
//! workers, measured end to end through the admission queue (no TCP, so
//! the numbers isolate the scheduler + engines, not socket overhead).
//!
//! Reports tokens/s, mean decode-batch occupancy, and p50/p99 request
//! latency per worker count — then a **shared-prefix workload** (every
//! client's prompt starts with the same 40-token head, the system-prompt
//! pattern) with the radix-tree prefix cache off and on, reporting
//! tokens/s plus `prefix_hit_tokens` / `prefill_tokens` so the skipped
//! prefill work is visible, and a **speculative workload** (repeat
//! traffic, cache on) with `--spec-decode` off / radix / self,
//! reporting tokens/s plus `drafted_tokens` / `accepted_tokens` /
//! `spec_rollbacks` — then a **tracing workload** (the uniform 2-worker
//! load with span recording off vs on, reporting the tokens/s delta and
//! `trace_dropped`, so the observability layer's overhead is a measured
//! number) — and finally a **router workload** (the same load
//! pushed over TCP through the router tier fronting two real engine
//! backends), once healthy and once with one backend killed mid-run by
//! an injected `backend_down` fault, reporting tokens/s plus the
//! routing counters (`hash_routed` / `spilled` / `failovers`) so the
//! cost of degraded operation is a number, not a guess. Set
//! `SALR_BENCH_JSON=path.json` to emit machine-readable results; env
//! knobs `SALR_BENCH_CLIENTS` (default 16), `SALR_BENCH_REQS` (default
//! 4 per client) and `SALR_BENCH_CHUNK` (prefill chunk, default 64,
//! 0 = whole-prompt) scale the load.
//!
//! Run: `cargo bench --bench bench_serve`

use salr::infer::{Backend, Engine, EngineWeights, SpecMode};
use salr::model::ParamStore;
use salr::runtime::ModelCfg;
use salr::server::{
    serve_on, serve_router_on, spawn_engine_workers, BatchPolicy, Batcher, Client, Request,
    Router, RouterPolicy,
};
use salr::util::fault::FaultPlan;
use salr::util::json::Json;
use salr::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

const WORKER_COUNTS: [usize; 3] = [1, 2, 4];

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn bench_engine() -> Engine {
    let cfg = ModelCfg {
        name: "bench-serve".into(),
        vocab_size: 64,
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 128,
        max_seq_len: 64,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 4,
        batch_size: 8,
        ctx_keep: 0.5,
    };
    let mut rng = Rng::new(7001);
    let base = ParamStore::init_base(&cfg, &mut rng);
    Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
}

struct RunResult {
    workers: usize,
    wall_s: f64,
    tokens: u64,
    requests: u64,
    occupancy: f64,
    p50_ms: f64,
    p99_ms: f64,
    faults: FailureCounters,
}

/// The serving tier's failure-plane counters, snapshotted per run and
/// surfaced in the bench JSON: a healthy bench reports all zeros, and a
/// bench run under `SALR_FAULT` (or one that trips shedding under load)
/// shows exactly what failed instead of silently skewing tokens/s.
#[derive(Clone, Copy, Default)]
struct FailureCounters {
    shed: u64,
    cancelled: u64,
    timed_out: u64,
    worker_restarts: u64,
}

impl FailureCounters {
    fn snapshot(batcher: &Batcher) -> FailureCounters {
        FailureCounters {
            shed: batcher.metrics.shed.load(Ordering::Relaxed),
            cancelled: batcher.metrics.cancelled.load(Ordering::Relaxed),
            timed_out: batcher.metrics.timed_out.load(Ordering::Relaxed),
            worker_restarts: batcher.metrics.worker_restarts.load(Ordering::Relaxed),
        }
    }

    fn accumulate(&mut self, other: FailureCounters) {
        self.shed += other.shed;
        self.cancelled += other.cancelled;
        self.timed_out += other.timed_out;
        self.worker_restarts += other.worker_restarts;
    }
}

struct SharedPrefixResult {
    prefix_cache: bool,
    wall_s: f64,
    tokens: u64,
    prefix_hit_tokens: u64,
    prefill_tokens: u64,
    faults: FailureCounters,
}

/// The shared-prefix workload: `clients` concurrent clients, each
/// submitting `reqs_per_client` prompts that all start with the same
/// 40-token head (distinct tails), against 2 engine workers.
fn run_shared_prefix_load(
    template: &Engine,
    clients: usize,
    reqs_per_client: usize,
    prefix_cache: bool,
) -> SharedPrefixResult {
    // 40-byte head + short distinct tail; prompt + 16 generated tokens
    // stays inside the bench engine's 64-token context.
    let head = "SYSTEM: you are a terse math assistant.\n";
    assert_eq!(head.len(), 40);
    let policy = BatchPolicy {
        max_batch: 8,
        engine_workers: 2,
        prefill_chunk: env_usize("SALR_BENCH_CHUNK", 64),
        kv_block_size: 8,
        prefix_cache,
        ..Default::default()
    };
    let batcher = Batcher::new(policy);
    let handles = spawn_engine_workers(&batcher, template.fork());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let b = batcher.clone();
            s.spawn(move || {
                for r in 0..reqs_per_client {
                    let resp = b.submit(Request {
                        id: (c * reqs_per_client + r) as u64,
                        prompt: format!("{head}{}+{}=", 10 + c % 10, r % 10),
                        max_tokens: 16,
                        ..Default::default()
                    });
                    assert_eq!(resp.tokens, 16);
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let res = SharedPrefixResult {
        prefix_cache,
        wall_s,
        tokens: batcher.metrics.tokens_out.load(Ordering::Relaxed),
        prefix_hit_tokens: batcher.metrics.prefix_hit_tokens.load(Ordering::Relaxed),
        prefill_tokens: batcher.metrics.prefill_tokens.load(Ordering::Relaxed),
        faults: FailureCounters::snapshot(&batcher),
    };
    batcher.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    res
}

struct SpecResult {
    mode: SpecMode,
    wall_s: f64,
    tokens: u64,
    drafted: u64,
    accepted: u64,
    rollbacks: u64,
    faults: FailureCounters,
}

/// The speculative workload: repeat traffic (every client cycles the
/// same 4 prompts) with the prefix cache on, served with speculation
/// off / radix / self. Repeats are the radix drafter's best case —
/// after the first round each completion is drafted from the tree and
/// accepted in full — so the off-vs-radix delta bounds what drafting
/// buys, and the counters show the acceptance rate behind it.
fn run_speculative_load(
    template: &Engine,
    clients: usize,
    reqs_per_client: usize,
    mode: SpecMode,
) -> SpecResult {
    let policy = BatchPolicy {
        max_batch: 8,
        engine_workers: 2,
        prefill_chunk: env_usize("SALR_BENCH_CHUNK", 64),
        kv_block_size: 8,
        prefix_cache: true,
        spec_decode: mode,
        spec_k: 4,
        ..Default::default()
    };
    let batcher = Batcher::new(policy);
    let handles = spawn_engine_workers(&batcher, template.fork());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let b = batcher.clone();
            s.spawn(move || {
                for r in 0..reqs_per_client {
                    let resp = b.submit(Request {
                        id: (c * reqs_per_client + r) as u64,
                        // 4 distinct prompts shared by every client.
                        prompt: format!("Q: {}+{}=? A: ", 3 + (c + r) % 4, 20 - (c + r) % 4),
                        max_tokens: 16,
                        ..Default::default()
                    });
                    assert_eq!(resp.tokens, 16);
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let res = SpecResult {
        mode,
        wall_s,
        tokens: batcher.metrics.tokens_out.load(Ordering::Relaxed),
        drafted: batcher.metrics.drafted_tokens.load(Ordering::Relaxed),
        accepted: batcher.metrics.accepted_tokens.load(Ordering::Relaxed),
        rollbacks: batcher.metrics.spec_rollbacks.load(Ordering::Relaxed),
        faults: FailureCounters::snapshot(&batcher),
    };
    batcher.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    res
}

struct RouterResult {
    degraded: bool,
    wall_s: f64,
    completed: u64,
    lost: u64,
    routed: u64,
    hash_routed: u64,
    spilled: u64,
    failovers: u64,
}

/// One real TCP engine backend for the router workload (fault-free and
/// env-insulated: router rows inject faults at the router, never here).
fn start_router_backend(
    template: &Engine,
    chunk: usize,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let policy = BatchPolicy {
        max_batch: 8,
        engine_workers: 1,
        prefill_chunk: chunk,
        kv_block_size: 8,
        prefix_cache: false,
        ..Default::default()
    };
    let batcher = Batcher::with_fault(policy, None);
    let engine = template.fork();
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve_on(engine, "127.0.0.1:0", batcher, Some(tx)).expect("router bench backend");
    });
    (rx.recv().expect("backend ready"), handle)
}

/// The router workload: the full load over TCP through the router tier
/// fronting two single-worker engine backends. The degraded row kills
/// backend 0 partway through with an injected `backend_down` fault
/// (keyed on its delivered-frame counter, so the kill point scales with
/// the load): unstarted requests fail over and complete, anything
/// mid-stream gets the clean `backend lost` error, and the tokens/s
/// delta prices the half-fleet + failover re-execution cost.
fn run_router_load(
    template: &Engine,
    clients: usize,
    reqs_per_client: usize,
    degraded: bool,
) -> RouterResult {
    let chunk = env_usize("SALR_BENCH_CHUNK", 64);
    let (a0, h0) = start_router_backend(template, chunk);
    let (a1, h1) = start_router_backend(template, chunk);
    let fault = if degraded {
        let at = (clients * reqs_per_client / 4).max(2);
        Some(FaultPlan::parse(&format!("backend_down:backend=0,reply={at}")).expect("bench fault"))
    } else {
        None
    };
    let policy = RouterPolicy { heartbeat_ms: 20, ..RouterPolicy::default() };
    let router = Router::with_fault(&[a0.to_string(), a1.to_string()], policy, fault);
    let (tx, rx) = std::sync::mpsc::channel();
    let r = router.clone();
    let router_handle = std::thread::spawn(move || {
        serve_router_on(r, "127.0.0.1:0", Some(tx)).expect("router bench");
    });
    let ra = rx.recv().expect("router ready");
    {
        // Loading before the first heartbeat probe lands would measure
        // `no healthy backend` rejections, not routing.
        let mut probe = Client::connect(&ra.to_string()).unwrap();
        let t0 = Instant::now();
        loop {
            let m = probe.metrics().unwrap();
            let healthy = m
                .get("backends")
                .and_then(Json::as_arr)
                .map(|bs| {
                    bs.iter()
                        .filter(|b| {
                            b.get("backend_state").and_then(Json::as_str) == Some("healthy")
                        })
                        .count()
                })
                .unwrap_or(0);
            if healthy == 2 {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "backends never became healthy");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    let t0 = Instant::now();
    let (mut completed, mut lost) = (0u64, 0u64);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let addr = ra.to_string();
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let (mut ok, mut err) = (0u64, 0u64);
                    for r in 0..reqs_per_client {
                        let resp = client
                            .generate(&format!("Q: {}+{}=? A: ", 10 + c % 10, 3 + r % 10), 16)
                            .unwrap();
                        if resp.get("error").is_some() {
                            err += 1;
                        } else {
                            ok += 1;
                        }
                    }
                    (ok, err)
                })
            })
            .collect();
        for h in handles {
            let (ok, err) = h.join().unwrap();
            completed += ok;
            lost += err;
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let m = router.metrics_json();
    let counter = |k: &str| m.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let res = RouterResult {
        degraded,
        wall_s,
        completed,
        lost,
        routed: counter("routed"),
        hash_routed: counter("hash_routed"),
        spilled: counter("spilled"),
        failovers: counter("failovers"),
    };
    Client::connect(&ra.to_string()).unwrap().shutdown().unwrap();
    router_handle.join().unwrap();
    for (a, h) in [(a0, h0), (a1, h1)] {
        Client::connect(&a.to_string()).unwrap().shutdown().unwrap();
        h.join().unwrap();
    }
    res
}

fn run_load(template: &Engine, workers: usize, clients: usize, reqs_per_client: usize) -> RunResult {
    let policy = BatchPolicy {
        max_batch: 8,
        engine_workers: workers,
        prefill_chunk: env_usize("SALR_BENCH_CHUNK", 64),
        // Pinned, not env-inherited: uniform-mode rows must measure the
        // same configuration on every host (the CI/verify docs set
        // SALR_PREFIX_CACHE, which would otherwise leak in here).
        kv_block_size: 16,
        prefix_cache: false,
        ..Default::default()
    };
    let batcher = Batcher::new(policy);
    let handles = spawn_engine_workers(&batcher, template.fork());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let b = batcher.clone();
            s.spawn(move || {
                for r in 0..reqs_per_client {
                    let resp = b.submit(Request {
                        id: (c * reqs_per_client + r) as u64,
                        prompt: format!("Q: {}+{}=? A: ", 10 + c, 3 + r),
                        max_tokens: 16,
                        ..Default::default()
                    });
                    assert_eq!(resp.tokens, 16);
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let (p50, _p90, p99) = batcher.metrics.latency_percentiles();
    let res = RunResult {
        workers,
        wall_s,
        tokens: batcher.metrics.tokens_out.load(Ordering::Relaxed),
        requests: batcher.metrics.requests.load(Ordering::Relaxed),
        occupancy: batcher.metrics.mean_batch_occupancy(),
        p50_ms: p50,
        p99_ms: p99,
        faults: FailureCounters::snapshot(&batcher),
    };
    batcher.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    res
}

fn main() {
    let clients = env_usize("SALR_BENCH_CLIENTS", 16);
    let reqs = env_usize("SALR_BENCH_REQS", 4);
    let template = bench_engine();
    println!("# continuous-batching serving throughput");
    println!("# load: {clients} clients x {reqs} requests x 16 tokens\n");
    // Warm the kernels/pools once so t=1 is not charged for cold start.
    let _ = run_load(&template, 1, 2, 1);

    let mut rows = Vec::new();
    for &w in &WORKER_COUNTS {
        let r = run_load(&template, w, clients, reqs);
        println!(
            "engine_workers={:<2} {:>8.1} tok/s  occupancy {:>5.2}  p50 {:>7.1} ms  p99 {:>7.1} ms  ({} reqs in {:.2}s)",
            r.workers,
            r.tokens as f64 / r.wall_s,
            r.occupancy,
            r.p50_ms,
            r.p99_ms,
            r.requests,
            r.wall_s,
        );
        rows.push(r);
    }

    let mut faults = FailureCounters::default();
    for r in &rows {
        faults.accumulate(r.faults);
    }

    println!("\n# shared-prefix workload: {clients} clients x {reqs} reqs, common 40-token head, 2 workers");
    let mut shared_rows = Vec::new();
    for prefix_cache in [false, true] {
        let r = run_shared_prefix_load(&template, clients, reqs, prefix_cache);
        println!(
            "prefix_cache={:<5} {:>8.1} tok/s  prefix_hit_tokens {:>6}  prefill_tokens {:>6}",
            r.prefix_cache,
            r.tokens as f64 / r.wall_s,
            r.prefix_hit_tokens,
            r.prefill_tokens,
        );
        faults.accumulate(r.faults);
        shared_rows.push(r);
    }
    println!("\n# speculative workload: {clients} clients x {reqs} reqs, repeat traffic, cache on, 2 workers, k=4");
    let mut spec_rows = Vec::new();
    for mode in [SpecMode::Off, SpecMode::Radix, SpecMode::SelfDraft] {
        let r = run_speculative_load(&template, clients, reqs, mode);
        println!(
            "spec={:<5} {:>8.1} tok/s  drafted {:>6}  accepted {:>6}  rollbacks {:>4}",
            r.mode.name(),
            r.tokens as f64 / r.wall_s,
            r.drafted,
            r.accepted,
            r.rollbacks,
        );
        faults.accumulate(r.faults);
        spec_rows.push(r);
    }
    println!("\n# tracing workload: {clients} clients x {reqs} reqs, 2 workers, span recording off vs on");
    let mut trace_rows = Vec::new();
    for traced in [false, true] {
        salr::util::trace::set_enabled(traced);
        let r = run_load(&template, 2, clients, reqs);
        println!(
            "trace={:<5} {:>8.1} tok/s  p50 {:>7.1} ms  p99 {:>7.1} ms  trace_dropped {:>6}",
            traced,
            r.tokens as f64 / r.wall_s,
            r.p50_ms,
            r.p99_ms,
            salr::util::trace::dropped(),
        );
        faults.accumulate(r.faults);
        trace_rows.push((traced, r));
    }
    // Off again so the router rows below measure untraced serving.
    salr::util::trace::set_enabled(false);

    println!("\n# router workload: {clients} clients x {reqs} reqs over TCP, 2 backends x 1 worker");
    let mut router_rows = Vec::new();
    for degraded in [false, true] {
        let r = run_router_load(&template, clients, reqs, degraded);
        println!(
            "degraded={:<5} {:>8.1} tok/s  completed {:>4}  lost {:>3}  hash_routed {:>4}  spilled {:>4}  failovers {:>3}",
            r.degraded,
            (r.completed * 16) as f64 / r.wall_s,
            r.completed,
            r.lost,
            r.hash_routed,
            r.spilled,
            r.failovers,
        );
        router_rows.push(r);
    }
    println!(
        "\n# failure counters (all engine-local runs): shed {}  cancelled {}  timeout {}  worker_restarts {}",
        faults.shed, faults.cancelled, faults.timed_out, faults.worker_restarts
    );

    if let Ok(path) = std::env::var("SALR_BENCH_JSON") {
        let mut result_rows: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj()
                    .set("mode", "uniform")
                    .set("engine_workers", r.workers)
                    .set("tokens_per_sec", r.tokens as f64 / r.wall_s)
                    .set("mean_batch_occupancy", r.occupancy)
                    .set("latency_p50_ms", r.p50_ms)
                    .set("latency_p99_ms", r.p99_ms)
                    .set("requests", r.requests)
                    .set("wall_s", r.wall_s)
            })
            .collect();
        result_rows.extend(shared_rows.iter().map(|r| {
            Json::obj()
                .set("mode", "shared_prefix")
                .set("engine_workers", 2usize)
                .set("prefix_cache", r.prefix_cache)
                .set("tokens_per_sec", r.tokens as f64 / r.wall_s)
                .set("prefix_hit_tokens", r.prefix_hit_tokens)
                .set("prefill_tokens", r.prefill_tokens)
                .set("wall_s", r.wall_s)
        }));
        result_rows.extend(spec_rows.iter().map(|r| {
            Json::obj()
                .set("mode", "speculative")
                .set("engine_workers", 2usize)
                .set("spec_decode", r.mode.name())
                .set("spec_k", 4usize)
                .set("tokens_per_sec", r.tokens as f64 / r.wall_s)
                .set("drafted_tokens", r.drafted)
                .set("accepted_tokens", r.accepted)
                .set("spec_rollbacks", r.rollbacks)
                .set("wall_s", r.wall_s)
        }));
        result_rows.extend(trace_rows.iter().map(|(traced, r)| {
            Json::obj()
                .set("mode", "traced")
                .set("engine_workers", 2usize)
                .set("trace", *traced)
                .set("tokens_per_sec", r.tokens as f64 / r.wall_s)
                .set("latency_p50_ms", r.p50_ms)
                .set("latency_p99_ms", r.p99_ms)
                .set("wall_s", r.wall_s)
        }));
        result_rows.extend(router_rows.iter().map(|r| {
            Json::obj()
                .set("mode", "router")
                .set("backends", 2usize)
                .set("degraded", r.degraded)
                .set("tokens_per_sec", (r.completed * 16) as f64 / r.wall_s)
                .set("completed", r.completed)
                .set("lost", r.lost)
                .set("routed", r.routed)
                .set("hash_routed", r.hash_routed)
                .set("spilled", r.spilled)
                .set("failovers", r.failovers)
                .set("wall_s", r.wall_s)
        }));
        let results = Json::Arr(result_rows);
        let meta = Json::obj()
            .set("bench", "serve")
            .set("clients", clients)
            .set("reqs_per_client", reqs)
            .set("tokens_per_req", 16)
            .set("prefill_chunk", env_usize("SALR_BENCH_CHUNK", 64))
            .set("host_threads", salr::util::pool::available_threads())
            // Failure-plane counters across every run: all zeros on a
            // healthy bench, nonzero under SALR_FAULT or overload.
            .set("shed", faults.shed)
            .set("cancelled", faults.cancelled)
            .set("timeout", faults.timed_out)
            .set("worker_restarts", faults.worker_restarts);
        salr::util::bench::write_bench_doc(&path, meta, results)
            .expect("write bench json");
        println!("\nwrote {path}");
    }
}
