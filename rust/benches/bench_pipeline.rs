//! The two-stage pipeline ablation: decode-then-GEMM, fused pack-decode,
//! direct zero-skipping, and the pipelined ring-buffer design at several
//! depths, panel sizes and worker counts — the system core of the paper's
//! inference speedup.

use salr::gemm::dense::gemm_src_pool;
use salr::gemm::pipeline::{gemm_pipelined, salr_gemm_pipelined, PipelineConfig};
use salr::gemm::sparse::{sparse_gemm_direct, sparse_gemm_direct_pool};
use salr::prune::prune_global;
use salr::sparse::BitmapMatrix;
use salr::tensor::Tensor;
use salr::util::bench::{black_box, Bench};
use salr::util::pool::WorkerPool;
use salr::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(4);
    println!(
        "micro-kernel dispatch: {}\n",
        salr::gemm::kernel::Kernel::active().name()
    );
    let (m, k, n) = (8usize, 1024usize, 1024usize);
    let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
    prune_global(&mut [&mut w], 0.5);
    let bm = BitmapMatrix::encode(&w);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let mut c = vec![0.0f32; m * n];
    let flops = 2.0 * (m * k * n) as f64;

    println!("# decode+GEMM strategies ({m}x{k}x{n} @50%)\n");
    let mut b = Bench::new();
    // Pinned to one thread: this row is the genuinely-sequential naive
    // deployment every other strategy is compared against — materialize
    // the dense matrix once up front, then run a plain GEMM. (Scratch is
    // arena-internal everywhere now — steady-state iterations allocate
    // nothing, so the harness measures kernels, not malloc.)
    let serial = WorkerPool::with_threads(1);
    let dense = bm.decode();
    b.run_with_work("decode-then-GEMM (pre-decoded dense)", flops, &mut || {
        salr::gemm::dense::gemm_f32_pool(x.data(), dense.data(), &mut c, m, k, n, &serial);
        black_box(&c);
    });
    // Fused pack-decode: the same dense micro-kernel, but each K×NR panel
    // is expanded from the bitmap inside the pack step — no resident
    // dense W anywhere.
    b.run_with_work("fused pack-decode (per-tile expand)", flops, &mut || {
        gemm_src_pool(x.data(), &bm, &mut c, m, &serial);
        black_box(&c);
    });
    b.run_with_work("direct (zero-skipping, no decode)", flops, &mut || {
        sparse_gemm_direct(x.data(), &bm, &mut c, m);
        black_box(&c);
    });
    // The decode-hot-path kernels striped across the pool (bitwise
    // identical to their serial rows above at every width).
    for &t in &[2usize, 4] {
        let pool = WorkerPool::with_threads(t);
        b.run_with_work(&format!("direct striped t={t}"), flops, &mut || {
            sparse_gemm_direct_pool(x.data(), &bm, &mut c, m, &pool);
            black_box(&c);
        });
        b.run_with_work(&format!("fused pack-decode t={t}"), flops, &mut || {
            gemm_src_pool(x.data(), &bm, &mut c, m, &pool);
            black_box(&c);
        });
    }
    for &(panel, depth) in &[(32usize, 2usize), (64, 3), (128, 3), (256, 4)] {
        b.run_with_work(
            &format!("pipelined panel={panel} depth={depth}"),
            flops,
            &mut || {
                gemm_pipelined(
                    x.data(),
                    &bm,
                    &mut c,
                    m,
                    PipelineConfig {
                        panel_k: panel,
                        ring_depth: depth,
                        num_threads: 0,
                    },
                );
                black_box(&c);
            },
        );
    }
    // Worker-count scaling at the default geometry.
    for &t in &[1usize, 2, 4, 8] {
        b.run_with_work(&format!("pipelined panel=64 depth=3 t={t}"), flops, &mut || {
            gemm_pipelined(x.data(), &bm, &mut c, m, PipelineConfig::with_threads(t));
            black_box(&c);
        });
    }
    println!("{}", b.comparison_table("two-stage pipeline"));

    // With adapters folded in (the full SALR linear).
    let r_total = 32usize;
    let a_cat = Tensor::randn(&[k, r_total], 0.1, &mut rng);
    let b_cat = Tensor::randn(&[r_total, n], 0.1, &mut rng);
    let mut b2 = Bench::new();
    for &t in &[1usize, 2, 4] {
        b2.run_with_work(
            &format!("salr linear (pipelined + fused adapters) t={t}"),
            flops,
            &mut || {
                salr_gemm_pipelined(
                    x.data(),
                    &bm,
                    a_cat.data(),
                    b_cat.data(),
                    r_total,
                    &mut c,
                    m,
                    PipelineConfig::with_threads(t),
                );
                black_box(&c);
            },
        );
    }
    // Dense baseline at the same shape.
    b2.run_with_work("dense GEMM (pre-decoded baseline)", flops, &mut || {
        salr::gemm::dense::gemm_f32(x.data(), dense.data(), &mut c, m, k, n);
        black_box(&c);
    });
    println!("{}", b2.comparison_table("SALR linear vs dense"));
}
