//! Dense GEMM throughput across shapes (the compute stage's roofline on
//! this machine — the denominator of every speedup claim).

use salr::gemm::dense::{gemm_f32, gemm_flops};
use salr::tensor::Tensor;
use salr::util::bench::{black_box, Bench};
use salr::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(2);
    println!("# dense GEMM roofline\n");
    let mut b = Bench::new();
    for &(m, k, n) in &[
        (8usize, 512usize, 512usize),   // decode-batch shape
        (64, 512, 512),
        (256, 256, 256),
        (512, 512, 512),
        (128, 1024, 1024),
        (1024, 128, 1024),              // adapter-concat-ish tall/skinny
    ] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut c = vec![0.0f32; m * n];
        let flops = gemm_flops(m, k, n);
        let stats = b.run_with_work(&format!("gemm {m}x{k}x{n}"), flops, &mut || {
            gemm_f32(a.data(), w.data(), &mut c, m, k, n);
            black_box(&c);
        });
        println!("    → {:.2} GFLOP/s", stats.rate() / 1e9);
    }
    println!("{}", b.comparison_table("dense GEMM"));
}
