//! Dense GEMM throughput across shapes, thread counts **and micro-kernel
//! dispatch** (the compute stage's roofline on this machine — the
//! denominator of every speedup claim), the pipelined SALR GEMM vs the
//! decode-then-GEMM baseline at the same thread counts, and the
//! compressed-resident comparison: decode-then-GEMM vs fused pack-decode
//! per weight format (bitmap, nf4).
//!
//! The scalar-vs-SIMD rows pin the micro-kernel explicitly
//! (`gemm_f32_pool_with_kernel`), so a single run on one host measures
//! both code paths; the dispatched rows show what production gets.
//!
//! Set `SALR_BENCH_JSON=path.json` to emit machine-readable results (the
//! `BENCH_gemm.json` perf-trajectory file is regenerated this way).

use salr::gemm::dense::{
    gemm_f32_acc_pool, gemm_f32_pool, gemm_f32_pool_with_kernel, gemm_flops, gemm_src_pool,
};
use salr::gemm::kernel::Kernel;
use salr::gemm::pipeline::{salr_gemm_pipelined, PipelineConfig};
use salr::model::{WeightFormat, WeightStore};
use salr::prune::prune_global;
use salr::sparse::BitmapMatrix;
use salr::tensor::Tensor;
use salr::util::bench::{black_box, Bench};
use salr::util::json::Json;
use salr::util::pool::WorkerPool;
use salr::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

const SHAPES: [(usize, usize, usize); 6] = [
    (8, 512, 512), // decode-batch shape
    (64, 512, 512),
    (256, 256, 256),
    (512, 512, 512),
    (128, 1024, 1024),
    (1024, 128, 1024), // adapter-concat-ish tall/skinny
];

fn main() {
    let mut rng = Rng::new(2);
    let mut b = Bench::new();
    let dispatched = Kernel::active();
    println!("micro-kernel dispatch: {}\n", dispatched.name());

    println!("# dense GEMM roofline — thread scaling (dispatched kernel)\n");
    for &(m, k, n) in &SHAPES {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut c = vec![0.0f32; m * n];
        let flops = gemm_flops(m, k, n);
        for &t in &THREADS {
            let pool = WorkerPool::with_threads(t);
            let stats = b.run_with_work(&format!("dense {m}x{k}x{n} t={t}"), flops, &mut || {
                gemm_f32_pool(a.data(), w.data(), &mut c, m, k, n, &pool);
                black_box(&c);
            });
            println!("    → {:.2} GFLOP/s", stats.rate() / 1e9);
        }
    }
    println!("{}", b.comparison_table("dense GEMM (thread scaling)"));

    // Scalar vs SIMD on the same shape set at a fixed thread count: the
    // micro-kernel speedup in isolation (identical bits, different speed).
    println!(
        "# dense GEMM — scalar vs dispatched ({}) micro-kernel, t=4\n",
        dispatched.name()
    );
    let mut bk = Bench::new();
    let kpool = WorkerPool::with_threads(4);
    // One scalar row per shape, plus the dispatched row when dispatch
    // actually selected a SIMD kernel — on scalar-only hosts (or under
    // SALR_FORCE_SCALAR=1) the second row would duplicate the first under
    // the same name, polluting the JSON with a meaningless self-speedup.
    let mut kernels = vec![(Kernel::scalar(), "scalar")];
    if dispatched.name() != "scalar" {
        kernels.push((dispatched, dispatched.name()));
    } else {
        println!("    (dispatch is scalar on this host — skipping SIMD rows)");
    }
    for &(m, k, n) in &SHAPES {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut c = vec![0.0f32; m * n];
        let flops = gemm_flops(m, k, n);
        for &(kern, tag) in &kernels {
            let stats = bk.run_with_work(
                &format!("dense {m}x{k}x{n} t=4 kern={tag}"),
                flops,
                &mut || {
                    gemm_f32_pool_with_kernel(a.data(), w.data(), &mut c, m, k, n, &kpool, kern);
                    black_box(&c);
                },
            );
            println!("    → {:.2} GFLOP/s", stats.rate() / 1e9);
        }
    }
    println!("{}", bk.comparison_table("scalar vs SIMD micro-kernel"));

    // Pipelined SALR GEMM at 50% sparsity vs the decode-then-GEMM
    // baseline, per thread count.
    let (m, k, n, r) = (64usize, 1024usize, 1024usize, 32usize);
    let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
    prune_global(&mut [&mut w], 0.5);
    let bm = BitmapMatrix::encode(&w);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let a_cat = Tensor::randn(&[k, r], 0.1, &mut rng);
    let b_cat = Tensor::randn(&[r, n], 0.1, &mut rng);
    let mut c = vec![0.0f32; m * n];
    let mut u = vec![0.0f32; m * r];
    let flops = gemm_flops(m, k, n);
    println!("# pipelined SALR GEMM ({m}x{k}x{n} @50%) vs decode-then-GEMM\n");
    // Separate harness so the comparison table's speedup column is
    // relative to the decode-then-GEMM baseline, not the dense rows above.
    let mut bs = Bench::new();
    // The baseline does the same math as the pipelined rows (full decode,
    // base GEMM, fused adapter update), pinned to the matching thread
    // count so the comparison isolates the *overlap*, not the core count.
    // The dense scratch is allocated once outside the timed loop so each
    // iteration measures decode + GEMM, not malloc.
    let mut wdense = vec![0.0f32; k * n];
    for &t in &THREADS {
        let pool = WorkerPool::with_threads(t);
        bs.run_with_work(&format!("salr decode-then-GEMM {m}x{k}x{n}@50% t={t}"), flops, &mut || {
            bm.decode_rows_into(0, k, &mut wdense);
            gemm_f32_pool(x.data(), &wdense, &mut c, m, k, n, &pool);
            gemm_f32_pool(x.data(), a_cat.data(), &mut u, m, k, r, &pool);
            gemm_f32_acc_pool(&u, b_cat.data(), &mut c, m, r, n, &pool);
            black_box(&c);
        });
    }
    for &t in &THREADS {
        bs.run_with_work(&format!("salr pipelined {m}x{k}x{n}@50% t={t}"), flops, &mut || {
            salr_gemm_pipelined(
                x.data(),
                &bm,
                a_cat.data(),
                b_cat.data(),
                r,
                &mut c,
                m,
                PipelineConfig {
                    num_threads: t,
                    ..Default::default()
                },
            );
            black_box(&c);
        });
    }
    println!("{}", bs.comparison_table("pipelined SALR vs decode-then-GEMM"));

    // Compressed-resident formats: decode-then-GEMM (expand the whole
    // matrix into a dense scratch, then plain GEMM) vs the fused
    // pack-decode path (each K×NR panel expanded from the compressed
    // bytes inside the pack step). Both rows start from the same
    // WeightStore, so per format the work differs only in *where* the
    // decode happens — this is the bandwidth argument of the
    // compressed-weight kernel path, measured.
    println!("# weight formats ({m}x{k}x{n} @50%): decode-then-GEMM vs fused pack-decode\n");
    let mut bf = Bench::new();
    let fpool = WorkerPool::with_threads(4);
    for &fmt in &[WeightFormat::Bitmap, WeightFormat::Nf4] {
        let store = WeightStore::encode(&w, fmt);
        bf.run_with_work(
            &format!("{} decode-then-GEMM t=4", fmt.name()),
            flops,
            &mut || {
                store.decode_rows_into(0, k, &mut wdense);
                gemm_f32_pool(x.data(), &wdense, &mut c, m, k, n, &fpool);
                black_box(&c);
            },
        );
        bf.run_with_work(
            &format!("{} fused pack-decode t=4", fmt.name()),
            flops,
            &mut || {
                gemm_src_pool(x.data(), &store, &mut c, m, &fpool);
                black_box(&c);
            },
        );
    }
    println!("{}", bf.comparison_table("decode placement per weight format"));

    if let Ok(path) = std::env::var("SALR_BENCH_JSON") {
        let meta = Json::obj()
            .set("bench", "gemm")
            .set(
                "threads_swept",
                Json::Arr(THREADS.iter().map(|&t| Json::from(t)).collect()),
            )
            .set("kernel_dispatch", dispatched.name())
            .set("provenance", "measured by benches/bench_gemm.rs");
        let mut all = match b.results_json() {
            Json::Arr(v) => v,
            _ => Vec::new(),
        };
        if let Json::Arr(v) = bk.results_json() {
            all.extend(v);
        }
        if let Json::Arr(v) = bs.results_json() {
            all.extend(v);
        }
        if let Json::Arr(v) = bf.results_json() {
            all.extend(v);
        }
        salr::util::bench::write_bench_doc(&path, meta, Json::Arr(all))
            .expect("write bench json");
        println!("wrote {path}");
    }
}
