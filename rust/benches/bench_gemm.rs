//! Dense GEMM throughput across shapes **and thread counts** (the compute
//! stage's roofline on this machine — the denominator of every speedup
//! claim), plus the pipelined SALR GEMM vs the sequential bitmap baseline
//! at the same thread counts.
//!
//! Set `SALR_BENCH_JSON=path.json` to emit machine-readable results (the
//! `BENCH_gemm.json` perf-trajectory file is regenerated this way).

use salr::gemm::dense::{gemm_f32_acc_pool, gemm_f32_pool, gemm_flops};
use salr::gemm::pipeline::{salr_gemm_pipelined, PipelineConfig};
use salr::gemm::sparse::bitmap_gemm_sequential_pool;
use salr::prune::prune_global;
use salr::sparse::BitmapMatrix;
use salr::tensor::Tensor;
use salr::util::bench::{black_box, Bench};
use salr::util::json::Json;
use salr::util::pool::WorkerPool;
use salr::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

fn main() {
    let mut rng = Rng::new(2);
    let mut b = Bench::new();

    println!("# dense GEMM roofline — thread scaling\n");
    for &(m, k, n) in &[
        (8usize, 512usize, 512usize), // decode-batch shape
        (64, 512, 512),
        (256, 256, 256),
        (512, 512, 512),
        (128, 1024, 1024),
        (1024, 128, 1024), // adapter-concat-ish tall/skinny
    ] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut c = vec![0.0f32; m * n];
        let flops = gemm_flops(m, k, n);
        for &t in &THREADS {
            let pool = WorkerPool::with_threads(t);
            let stats = b.run_with_work(&format!("dense {m}x{k}x{n} t={t}"), flops, &mut || {
                gemm_f32_pool(a.data(), w.data(), &mut c, m, k, n, &pool);
                black_box(&c);
            });
            println!("    → {:.2} GFLOP/s", stats.rate() / 1e9);
        }
    }
    println!("{}", b.comparison_table("dense GEMM (thread scaling)"));

    // Pipelined SALR GEMM at 50% sparsity vs the sequential bitmap
    // baseline, per thread count.
    let (m, k, n, r) = (64usize, 1024usize, 1024usize, 32usize);
    let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
    prune_global(&mut [&mut w], 0.5);
    let bm = BitmapMatrix::encode(&w);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let a_cat = Tensor::randn(&[k, r], 0.1, &mut rng);
    let b_cat = Tensor::randn(&[r, n], 0.1, &mut rng);
    let mut c = vec![0.0f32; m * n];
    let mut u = vec![0.0f32; m * r];
    let flops = gemm_flops(m, k, n);
    let mut scratch = Vec::new();
    println!("# pipelined SALR GEMM ({m}x{k}x{n} @50%) vs sequential\n");
    // Separate harness so the comparison table's speedup column is
    // relative to the sequential baseline, not the dense rows above.
    let mut bs = Bench::new();
    // Sequential baseline does the same math as the pipelined rows (base
    // GEMM + fused adapter update), pinned to the matching thread count so
    // the comparison isolates the *overlap*, not the core count.
    for &t in &THREADS {
        let pool = WorkerPool::with_threads(t);
        bs.run_with_work(&format!("salr sequential {m}x{k}x{n}@50% t={t}"), flops, &mut || {
            bitmap_gemm_sequential_pool(x.data(), &bm, &mut c, m, &mut scratch, &pool);
            gemm_f32_pool(x.data(), a_cat.data(), &mut u, m, k, r, &pool);
            gemm_f32_acc_pool(&u, b_cat.data(), &mut c, m, r, n, &pool);
            black_box(&c);
        });
    }
    for &t in &THREADS {
        bs.run_with_work(&format!("salr pipelined {m}x{k}x{n}@50% t={t}"), flops, &mut || {
            salr_gemm_pipelined(
                x.data(),
                &bm,
                a_cat.data(),
                b_cat.data(),
                r,
                &mut c,
                m,
                PipelineConfig {
                    num_threads: t,
                    ..Default::default()
                },
            );
            black_box(&c);
        });
    }
    println!("{}", bs.comparison_table("pipelined SALR vs sequential"));

    if let Ok(path) = std::env::var("SALR_BENCH_JSON") {
        let meta = Json::obj()
            .set("bench", "gemm")
            .set(
                "threads_swept",
                Json::Arr(THREADS.iter().map(|&t| Json::from(t)).collect()),
            )
            .set("provenance", "measured by benches/bench_gemm.rs");
        let mut all = match b.results_json() {
            Json::Arr(v) => v,
            _ => Vec::new(),
        };
        if let Json::Arr(v) = bs.results_json() {
            all.extend(v);
        }
        salr::util::bench::write_bench_doc(&path, meta, Json::Arr(all))
            .expect("write bench json");
        println!("wrote {path}");
    }
}
