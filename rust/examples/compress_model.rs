//! Compression example: the QSALR pipeline (Table 6) applied to the base
//! model at several operating points — dense f32, 50% bitmap, NF4, and
//! 20%-sparse + NF4 (QSALR) — with byte-exact file sizes and roundtrip
//! error per encoding.
//!
//! Run: `cargo run --release --example compress_model`
//! (needs AOT artifacts: `cd python && python -m compile.aot --out ../artifacts`)

use anyhow::Result;
use salr::eval::ExpContext;
use salr::model::{load_model, save_model, Encoding};
use salr::salr::{Baseline, BaselineSpec};
use salr::tensor::sub;

fn main() -> Result<()> {
    salr::util::logger::init();
    if std::env::var("SALR_PRETRAIN_STEPS").is_err() {
        std::env::set_var("SALR_PRETRAIN_STEPS", "60");
    }
    let ctx = ExpContext::new("artifacts", "tiny", "results")?;
    let base = ctx.base_model()?;
    let adapted: std::collections::HashSet<String> =
        ctx.cfg.adapted_layers().into_iter().collect();

    println!("== model compression operating points ==");
    println!(
        "{:<26} {:>12} {:>8} {:>12}",
        "encoding", "bytes", "ratio", "weight rel-err"
    );
    let dir = ctx.results_dir.join("compress_demo");
    std::fs::create_dir_all(&dir)?;

    let mut dense_bytes = 0u64;
    for (label, sparsity, enc) in [
        ("dense f32", 0.0, Encoding::Dense),
        ("bitmap @50%", 0.5, Encoding::Bitmap),
        ("NF4 (dense)", 0.0, Encoding::Nf4),
        ("QSALR: 20% + NF4", 0.2, Encoding::SparseNf4),
        ("bitmap+NF4 @50%", 0.5, Encoding::SparseNf4),
    ] {
        // Prune (if requested) with SALR's static Method-1 mask.
        let store = if sparsity > 0.0 {
            BaselineSpec::build(&ctx.cfg, &base, Baseline::Salr, sparsity, 3).params
        } else {
            base.clone()
        };
        let path = dir.join(format!("{}.salr", label.replace([' ', ':', '%', '+'], "_")));
        let bytes = save_model(&path, &store, |name, t| {
            if adapted.contains(name) && t.ndim() == 2 {
                enc
            } else {
                Encoding::Dense
            }
        })?;
        if dense_bytes == 0 {
            dense_bytes = bytes;
        }
        // Roundtrip error on one representative layer.
        let back = load_model(&path)?;
        let name = "layer0.w_in";
        let (orig, got) = (store.get(name).unwrap(), back.get(name).unwrap());
        let rel = if orig.fro_norm() > 0.0 {
            sub(got, orig).fro_norm() / orig.fro_norm()
        } else {
            0.0
        };
        println!(
            "{:<26} {:>12} {:>7.2}x {:>11.3}%",
            label,
            salr::util::human_bytes(bytes),
            dense_bytes as f64 / bytes as f64,
            rel * 100.0
        );
    }
    println!("\npaper Table 6 shape: QSALR ≈5x smaller than dense with minimal accuracy cost;");
    println!("bitmap @50% alone gives the paper's 2x (Fig. 1 / Table 3).");
    println!("compress_model OK");
    Ok(())
}
