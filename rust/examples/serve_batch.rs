//! Serving example: start the continuous-batching TCP server over a
//! SALR-deployed model (bitmap pipeline backend) with two engine
//! workers and chunked prefill, fire concurrent + pipelined client
//! requests, stream one response token by token, and report
//! latency/throughput/occupancy — the paper's deployment story end to
//! end.
//!
//! Run: `cargo run --release --example serve_batch`
//! (needs AOT artifacts: `cd python && python -m compile.aot --out ../artifacts`)

use anyhow::Result;
use salr::eval::{deploy_engine, ExpContext, RunKey, Task};
use salr::server::{serve, BatchPolicy, Client};
use salr::util::json::Json;
use std::time::Duration;

fn main() -> Result<()> {
    salr::util::logger::init();
    // Keep the demo snappy: a lightly-trained model is fine for serving.
    if std::env::var("SALR_STEPS").is_err() {
        std::env::set_var("SALR_STEPS", "40");
    }
    if std::env::var("SALR_PRETRAIN_STEPS").is_err() {
        std::env::set_var("SALR_PRETRAIN_STEPS", "60");
    }
    let ctx = ExpContext::new("artifacts", "tiny", "results")?;
    let key = RunKey {
        baseline: salr::salr::Baseline::Salr,
        task: Task::Math,
        sparsity: 0.5,
    };
    let (spec, adapters, _) = ctx.run(&key)?;
    let engine = deploy_engine(&ctx.cfg, &spec, &adapters, None)?;

    // Start the server on an ephemeral port: 2 continuous-batching engine
    // workers, 8 KV slots each, prefilling at most 16 prompt tokens per
    // scheduler iteration so long prompts never stall a worker's batch.
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(
            engine,
            "127.0.0.1:0",
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(4),
                engine_workers: 2,
                prefill_chunk: 16,
                ..Default::default()
            },
            Some(tx),
        )
    });
    let addr = rx.recv()?;
    println!("server up on {addr} (2 engine workers, prefill chunk 16)");

    // Streaming: tokens arrive frame by frame before the final reply.
    {
        let mut streamer = Client::connect(&addr.to_string())?;
        print!("  streaming \"Q: 6+7=? A: \" -> ");
        let fin = streamer.generate_stream("Q: 6+7=? A: ", 6, |delta| {
            print!("[{delta}]");
        })?;
        println!(
            "  (done: {} tokens in {:.1}ms)",
            fin.get("tokens").and_then(Json::as_usize).unwrap_or(0),
            fin.get("compute_ms").and_then(Json::as_f64).unwrap_or(0.0),
        );
    }

    // Fire 24 requests from 8 client threads. Each client *pipelines* its
    // 3 requests on one connection — replies come back in completion
    // order and are matched by id.
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..8u64 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<Vec<Json>> {
            let mut client = Client::connect(&addr)?;
            for i in 0..3u64 {
                let a = 10 + c * 7 + i;
                let b = 20 + i * 3;
                client.send(
                    &Json::obj()
                        .set("id", c * 3 + i)
                        .set("prompt", format!("Q: {a}+{b}=? A: "))
                        .set("max_tokens", 5u64),
                )?;
            }
            let mut replies = Vec::new();
            for _ in 0..3 {
                replies.push(client.recv()?);
            }
            Ok(replies)
        }));
    }
    let mut total_tokens = 0usize;
    let mut n = 0usize;
    for h in handles {
        for reply in h.join().unwrap()? {
            n += 1;
            total_tokens += reply.get("tokens").and_then(Json::as_usize).unwrap_or(0);
            if n <= 4 {
                println!(
                    "  sample reply: id={} text={:?} queue={:.1}ms compute={:.1}ms",
                    reply.get("id").and_then(Json::as_usize).unwrap_or(0),
                    reply.get("text").and_then(Json::as_str).unwrap_or(""),
                    reply.get("queue_ms").and_then(Json::as_f64).unwrap_or(0.0),
                    reply.get("compute_ms").and_then(Json::as_f64).unwrap_or(0.0),
                );
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // Pull server-side metrics, then shut down.
    let mut client = Client::connect(&addr.to_string())?;
    let metrics = client.metrics()?;
    println!("\n== serving metrics ==");
    println!(
        "  requests: {}  decode steps: {}  mean occupancy: {:.2}  midstream admissions: {}",
        metrics.get("requests").and_then(Json::as_usize).unwrap_or(0),
        metrics.get("decode_steps").and_then(Json::as_usize).unwrap_or(0),
        metrics
            .get("mean_batch_occupancy")
            .and_then(Json::as_f64)
            .unwrap_or(0.0),
        metrics
            .get("admitted_midstream")
            .and_then(Json::as_usize)
            .unwrap_or(0),
    );
    println!(
        "  latency p50/p90/p99: {:.1} / {:.1} / {:.1} ms",
        metrics.get("latency_p50_ms").and_then(Json::as_f64).unwrap_or(0.0),
        metrics.get("latency_p90_ms").and_then(Json::as_f64).unwrap_or(0.0),
        metrics.get("latency_p99_ms").and_then(Json::as_f64).unwrap_or(0.0),
    );
    println!(
        "  client-side: {n} requests, {total_tokens} tokens in {wall:.2}s → {:.1} tokens/s",
        total_tokens as f64 / wall
    );
    client.shutdown()?;
    server.join().unwrap()?;
    println!("serve_batch OK");
    Ok(())
}
