//! Quickstart: the smallest end-to-end SALR slice.
//!
//! Builds a random linear layer, prunes it at 50% with the static mask
//! (Theorem 2, Method 1), recovers the pruning residual with a rank-16
//! truncated-SVD adapter (Theorem 3), bitmap-encodes the sparse weight,
//! and runs the two-stage pipelined decode+GEMM — then checks the numbers
//! against the dense reference and prints the error/compression story.
//!
//! Run: `cargo run --release --example quickstart`

use salr::gemm::fused::AdapterStack;
use salr::gemm::pipeline::{salr_gemm_pipelined, PipelineConfig};
use salr::linalg::truncated_svd;
use salr::prune::{prune_global, theory};
use salr::salr::SalrLayer;
use salr::sparse::BitmapMatrix;
use salr::tensor::{matmul, mse, sub, Tensor};
use salr::util::rng::Rng;

fn main() {
    let (d_in, d_out, rank, res_rank, m) = (512usize, 512usize, 16usize, 16usize, 8usize);
    let mut rng = Rng::new(42);

    // A "pretrained" weight and a LoRA adapter pair.
    let w0 = Tensor::randn(&[d_in, d_out], 0.02, &mut rng);
    let lora_a = Tensor::randn(&[d_in, rank], 0.05, &mut rng);
    let lora_b = Tensor::randn(&[rank, d_out], 0.05, &mut rng);

    // 1. Static magnitude prune of the frozen base at p = 0.5 (Method 1).
    let mut w_hat = w0.clone();
    let threshold = prune_global(&mut [&mut w_hat], 0.5);
    println!(
        "pruned 50%: threshold {:.5}, sparsity {:.1}%",
        threshold,
        w_hat.sparsity() * 100.0
    );

    // Theorem 1: per-entry MSE vs the closed form.
    let emp = mse(&w0, &w_hat);
    let sigma2 = w0.sq_sum() / w0.len() as f64;
    println!(
        "prune MSE: measured {:.3e}, Theorem-1 closed form {:.3e} (≈0.072σ²)",
        emp,
        theory::mse_prune(0.5, sigma2)
    );

    // 2. Sparsity-preservation residual: rank-r SVD of E = W − Ŵ (Thm 3).
    let e = sub(&w0, &w_hat);
    let svd = truncated_svd(&e, res_rank, 7);
    let (res_a, res_b) = svd.into_adapter();
    let e_rec = matmul(&res_a, &res_b);
    let bound = (1.0 - res_rank as f64 / d_in.min(d_out) as f64) * emp;
    println!(
        "residual SVD (r={res_rank}): MSE {:.3e} ≤ bound {:.3e} ✓",
        mse(&e, &e_rec),
        bound
    );

    // 3. Bitmap encoding: true compression.
    let bm = BitmapMatrix::encode(&w_hat);
    println!(
        "bitmap: {} vs dense {} → {:.2}x compression",
        salr::util::human_bytes(bm.storage_bytes() as u64),
        salr::util::human_bytes(bm.dense_bytes() as u64),
        bm.compression_ratio()
    );

    // 4. Adapter concatenation + the two-stage pipelined SALR linear.
    // The layer holds a WeightStore — the bitmap stays the resident form
    // and the pipeline's pack step decodes it per panel.
    let store = salr::model::WeightStore::from_bitmap(bm);
    let layer = SalrLayer::new(store, &lora_a, &lora_b, 2.0, Some((&res_a, &res_b)));
    let x = Tensor::randn(&[m, d_in], 1.0, &mut rng);
    let mut y = vec![0.0f32; m * d_out];
    salr_gemm_pipelined(
        x.data(),
        &layer.base,
        layer.adapters.a_cat.data(),
        layer.adapters.b_cat.data(),
        layer.adapters.total_rank(),
        &mut y,
        m,
        PipelineConfig::default(),
    );
    let y = Tensor::from_vec(&[m, d_out], y);

    // Reference: dense everything.
    let mut scaled_a = lora_a.clone();
    scaled_a.scale(2.0);
    let stack = AdapterStack::concat(&[(&scaled_a, &lora_b), (&res_a, &res_b)]);
    let mut want = matmul(&x, &layer.base.decode()).into_vec();
    stack.apply_fused_acc(x.data(), m, &mut want);
    let want = Tensor::from_vec(&[m, d_out], want);
    let diff = salr::tensor::max_abs_diff(&y, &want);
    println!("pipelined SALR linear vs dense reference: max|Δ| = {diff:.2e}");
    assert!(diff < 1e-2);

    // How close is the SALR output to the *unpruned* model?
    let mut full = matmul(&x, &w0).into_vec();
    let mut lora_only = vec![0.0f32; m * d_out];
    AdapterStack::concat(&[(&scaled_a, &lora_b)]).apply_fused(x.data(), m, &mut lora_only);
    for (f, l) in full.iter_mut().zip(&lora_only) {
        *f += l;
    }
    let full = Tensor::from_vec(&[m, d_out], full);
    println!(
        "output error vs unpruned LoRA model: rel {:.3}% (residual adapter recovered the pruned mass)",
        sub(&y, &full).fro_norm() / full.fro_norm() * 100.0
    );
    println!("quickstart OK");
}
