//! End-to-end driver (DESIGN.md §6): the full SALR lifecycle on a real
//! small workload, proving all three layers compose.
//!
//!  1. pretrain a transformer on the synthetic corpus (AOT HLO steps on
//!     the PJRT CPU client — L2/L3);
//!  2. prune the base at 50% (Method 1) + build the truncated-SVD residual
//!     adapters (Theorem 3);
//!  3. fine-tune on the harder arithmetic task with the SALR step (Adam on
//!     LoRA, Theorem-4 η on the residual), logging the loss curve;
//!  4. also fine-tune the LoRA and LoSA baselines for comparison;
//!  5. evaluate exact-match accuracy through the native engine (L3, bitmap
//!     pipeline backend — L1's algorithm in deployment form);
//!  6. serialize the compressed model and report sizes.
//!
//! Run: `cargo run --release --example finetune_math`
//! (needs AOT artifacts: `cd python && python -m compile.aot --out ../artifacts`)
//! Env: SALR_PRETRAIN_STEPS / SALR_STEPS / SALR_EVAL_N scale the run.

use anyhow::Result;
use salr::eval::{deploy_engine, math_accuracy, ExpContext, RunKey, Task};
use salr::model::{save_model, Encoding};
use salr::salr::Baseline;

fn main() -> Result<()> {
    salr::util::logger::init();
    let ctx = ExpContext::new("artifacts", "tiny", "results")?;
    println!(
        "== SALR end-to-end: pretrain → prune+SVD → finetune → eval → compress =="
    );
    println!(
        "model: d_model={} layers={} params≈{}k | steps: pretrain={}, finetune={}",
        ctx.cfg.d_model,
        ctx.cfg.n_layers,
        467,
        ctx.scale.pretrain_steps,
        ctx.scale.finetune_steps
    );

    // --- 1. pretrain (cached) ---
    let t0 = std::time::Instant::now();
    let base = ctx.base_model()?;
    println!(
        "[1] base model ready ({} params, {:.1}s)",
        base.param_count(),
        t0.elapsed().as_secs_f64()
    );

    // --- 2..4: fine-tune SALR + baselines on the math task ---
    let mut rows = Vec::new();
    for b in [Baseline::Lora, Baseline::Losa, Baseline::Salr] {
        let key = RunKey {
            baseline: b,
            task: Task::Math,
            sparsity: 0.5,
        };
        let (spec, adapters, losses) = ctx.run(&key)?;
        if !losses.is_empty() {
            let k = losses.len() / 10;
            let curve: Vec<String> = losses
                .iter()
                .step_by(k.max(1))
                .map(|l| format!("{l:.3}"))
                .collect();
            println!("[{}] loss curve: {}", b.name(), curve.join(" → "));
        }
        // --- 5. evaluate on held-out problems ---
        let engine = deploy_engine(&ctx.cfg, &spec, &adapters, None)?;
        let test = salr::data::MathTask::finetune().test_examples(ctx.scale.eval_n);
        let (acc, _) = math_accuracy(&engine, &test, ctx.cfg.batch_size, 6);
        println!(
            "[{}] exact-match accuracy on {} held-out problems: {:.1}%",
            b.name(),
            test.len(),
            acc * 100.0
        );
        // --- 6. model size accounting ---
        let adapted: std::collections::HashSet<String> =
            ctx.cfg.adapted_layers().into_iter().collect();
        let path = ctx
            .results_dir
            .join(format!("e2e_{}.salr", b.name().replace(' ', "-")));
        let bytes = save_model(&path, &spec.params, |name, t| {
            if b.deploys_sparse() && adapted.contains(name) && t.ndim() == 2 {
                Encoding::Bitmap
            } else {
                Encoding::Dense
            }
        })?;
        println!(
            "[{}] serialized model: {}",
            b.name(),
            salr::util::human_bytes(bytes)
        );
        rows.push((b.name(), acc, bytes));
    }

    println!("\n== summary (Fig-1 shape: accuracy vs bytes) ==");
    let dense_bytes = rows[0].2 as f64;
    for (name, acc, bytes) in &rows {
        println!(
            "  {:<6} acc {:>5.1}%  size {:>10}  ({:.2}x of dense)",
            name,
            acc * 100.0,
            salr::util::human_bytes(*bytes),
            *bytes as f64 / dense_bytes
        );
    }
    println!("\nexpected shape: SALR ≈ LoRA accuracy at ~0.55x the bytes; LoSA smaller accuracy.");
    println!("finetune_math OK ({:.1}s total)", t0.elapsed().as_secs_f64());
    Ok(())
}
