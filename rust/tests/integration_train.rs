//! Training-loop integration over the AOT train-step artifacts: loss must
//! decrease, variants must run, and the SALR residual schedule must hold.
//! Skips cleanly when artifacts are absent.

use salr::data::{BatchBuilder, CorpusGen, MathTask};
use salr::model::ParamStore;
use salr::runtime::Runtime;
use salr::salr::{Baseline, BaselineSpec};
use salr::train::{finetune, pretrain, FinetuneData, StepLoop, TrainConfig};
use salr::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

#[test]
fn pretrain_loss_decreases() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let tc = TrainConfig {
        steps: 12,
        lr: 3e-3,
        seed: 1,
        log_every: 0,
        ..Default::default()
    };
    let (params, losses) = pretrain(&rt, &cfg, &tc).unwrap();
    assert_eq!(losses.len(), 12);
    assert!(
        losses[11] < losses[0],
        "pretrain loss should fall: {losses:?}"
    );
    assert_eq!(params.len(), ParamStore::init_base(&cfg, &mut Rng::new(0)).len());
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn all_finetune_variants_step_and_learn() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let mut rng = Rng::new(2);
    let base = ParamStore::init_base(&cfg, &mut rng);
    let data = FinetuneData::Math(MathTask::finetune().train_examples(256));
    for b in [
        Baseline::Lora,
        Baseline::Losa,
        Baseline::SparseLora,
        Baseline::DeepSparse,
        Baseline::Salr,
        Baseline::SalrFrozenResidual,
    ] {
        let mut spec = BaselineSpec::build(&cfg, &base, b, 0.5, 3);
        let tc = TrainConfig {
            steps: 8,
            lr: 2e-3,
            seed: 4,
            log_every: 0,
            mask_refresh: 4,
            ..Default::default()
        };
        let report = finetune(&rt, &cfg, &mut spec, &data, &tc).unwrap();
        assert_eq!(report.losses.len(), 8, "{b:?}");
        assert!(report.losses.iter().all(|l| l.is_finite()), "{b:?}");
        assert!(
            report.losses[7] < report.losses[0] + 0.5,
            "{b:?} diverged: {:?}",
            report.losses
        );
        // SALR uses a positive Theorem-4 eta; the frozen ablation uses 0.
        match b {
            Baseline::Salr => assert!(report.eta > 0.0),
            Baseline::SalrFrozenResidual => assert_eq!(report.eta, 0.0),
            _ => {}
        }
        // Adapters came back with the right keys.
        assert!(report.adapters.contains("layer0.wq.lora_a"));
        if b == Baseline::Salr {
            assert!(report.adapters.contains("layer0.wq.res_a"));
        }
    }
}

#[test]
fn residual_frozen_stays_fixed_through_hlo_steps() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let mut rng = Rng::new(5);
    let base = ParamStore::init_base(&cfg, &mut rng);
    let mut spec = BaselineSpec::build(&cfg, &base, Baseline::SalrFrozenResidual, 0.5, 6);
    let res_before = spec
        .residual
        .as_ref()
        .unwrap()
        .get("layer0.wq.res_a")
        .unwrap()
        .clone();
    let data = FinetuneData::Math(MathTask::finetune().train_examples(64));
    let tc = TrainConfig {
        steps: 4,
        lr: 2e-3,
        seed: 7,
        log_every: 0,
        ..Default::default()
    };
    let report = finetune(&rt, &cfg, &mut spec, &data, &tc).unwrap();
    let res_after = report.adapters.get("layer0.wq.res_a").unwrap();
    assert_eq!(
        &res_before, res_after,
        "frozen residual must not move (eta=0)"
    );
}

#[test]
fn steploop_feedback_updates_state() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let mut rng = Rng::new(8);
    let params = ParamStore::init_base(&cfg, &mut rng);
    let m = params.zeros_like();
    let v = params.zeros_like();
    let mut looph = StepLoop::new(
        &rt,
        "pretrain_tiny",
        &[("param:", &params), ("m:", &m), ("v:", &v)],
    )
    .unwrap();
    let mut corpus = CorpusGen::new(9);
    let bb = BatchBuilder::new(cfg.batch_size, cfg.max_seq_len);
    let windows: Vec<Vec<i32>> = (0..cfg.batch_size)
        .map(|_| corpus.next_window(cfg.max_seq_len))
        .collect();
    let batch = bb.from_windows(&windows);
    let l1 = looph.step(&batch, 1e-3, 0.0).unwrap();
    assert!(l1.is_finite());
    let after = looph.extract("param:");
    assert_eq!(after.len(), params.len());
    // Parameters actually moved.
    let before_w = params.get("layer0.wq").unwrap();
    let after_w = after.get("layer0.wq").unwrap();
    assert_ne!(before_w, after_w);
    assert_eq!(looph.steps_taken(), 1);
}
