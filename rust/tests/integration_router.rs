//! Router-tier integration: two real engine backends behind the router,
//! under cache-aware routing, injected network faults, failover and
//! graceful drain.
//!
//! Every failure is **deterministic**: network faults key on per-backend
//! op counters ([`FaultPlan`] kinds `conn_drop` / `backend_down` over
//! the `fwd` / `reply` ops, never wall-clock), the silent-backend test
//! uses a listener that accepts and never answers, and byte-identity is
//! always asserted against the single-backend sequential oracle —
//! greedy decode is deterministic, so any healthy placement (hash
//! owner, spill target, or failover target) must produce the same
//! bytes. The acceptance bar (ISSUE 9): under a mid-run `backend_down`,
//! every request either completes byte-identical to the oracle or gets
//! an explicit clean error, the router's inflight table drains to zero,
//! and the surviving backend's KV gauges return exactly to baseline.
//!
//! CI runs this file twice: once in the ordinary matrix (each test arms
//! its own explicit [`Router::with_fault`] plan) and once in the
//! router-fault leg with `SALR_FAULT=backend_down:backend=0,reply=3`,
//! where [`router_chaos_under_env_fault_spec`] additionally goes
//! through the production `Router::new` → env-parsing path.

use salr::data::{detokenize, tokenize};
use salr::infer::{Backend, Engine, EngineWeights};
use salr::model::ParamStore;
use salr::runtime::ModelCfg;
use salr::server::{serve_on, serve_router_on, BatchPolicy, Batcher, Client, Router, RouterPolicy};
use salr::util::fault::FaultPlan;
use salr::util::json::Json;
use salr::util::rng::Rng;
use std::net::SocketAddr;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn test_engine() -> Engine {
    let cfg = ModelCfg {
        name: "router-e2e".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        max_seq_len: 96,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 4,
        batch_size: 2,
        ctx_keep: 0.5,
    };
    let mut rng = Rng::new(500);
    let base = ParamStore::init_base(&cfg, &mut rng);
    Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
}

/// The fault-free single-backend reference bytes for one prompt.
fn oracle(engine: &Engine, prompt: &str, max_tokens: usize) -> String {
    let out = engine.generate_batch(&[tokenize(prompt)], max_tokens);
    detokenize(&out[0])
}

fn plan(spec: &str) -> Option<FaultPlan> {
    Some(FaultPlan::parse(spec).expect("test fault spec"))
}

/// Spin until `cond` holds (heartbeats, drains and gauge publication
/// all land a hair after the reply frames they follow).
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Engine policy shared by every backend in this file: prefix cache off
/// so the KV-gauge baseline is exactly zero.
fn backend_policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 4,
        engine_workers: 1,
        prefill_chunk: 4,
        prefix_cache: false,
        ..Default::default()
    }
}

/// One real engine backend on a private port, fault-free (router tests
/// inject faults at the router, never in the engines).
fn start_backend(engine: Engine) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let batcher = Batcher::with_fault(backend_policy(), None);
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve_on(engine, "127.0.0.1:0", batcher, Some(tx)).expect("backend serve");
    });
    (rx.recv().expect("backend ready"), handle)
}

/// Fast heartbeat, spill effectively off: placement in these tests is
/// decided by the hash ring (and faults), never by load.
fn router_policy() -> RouterPolicy {
    RouterPolicy {
        heartbeat_ms: 20,
        spill_depth: 1_000,
        ..RouterPolicy::default()
    }
}

fn start_router(router: &Arc<Router>) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let r = router.clone();
    let handle = std::thread::spawn(move || {
        serve_router_on(r, "127.0.0.1:0", Some(tx)).expect("router serve");
    });
    (rx.recv().expect("router ready"), handle)
}

fn router_over(
    addrs: &[SocketAddr],
    policy: RouterPolicy,
    fault: Option<FaultPlan>,
) -> Arc<Router> {
    let strs: Vec<String> = addrs.iter().map(|a| a.to_string()).collect();
    Router::with_fault(&strs, policy, fault)
}

/// One backend's object out of the router's metrics reply.
fn backend_obj(m: &Json, index: usize) -> Json {
    m.get("backends").and_then(Json::as_arr).expect("backends array")[index].clone()
}

fn backend_state(m: &Json, index: usize) -> String {
    backend_obj(m, index)
        .get("backend_state")
        .and_then(Json::as_str)
        .expect("backend_state")
        .to_string()
}

fn wait_all_healthy(router_addr: SocketAddr, n: usize) {
    let mut probe = Client::connect(&router_addr.to_string()).unwrap();
    wait_until("all backends healthy", || {
        let m = probe.metrics().unwrap();
        (0..n).all(|i| backend_state(&m, i) == "healthy")
    });
}

/// A prompt whose consistent-hash ring owner is backend `owner`.
fn prompt_owned_by(router: &Router, owner: usize, tag: &str) -> String {
    for i in 0..10_000 {
        let p = format!("Q: {tag}{i}+2=? A: ");
        if router.owner_of_prompt(&p) == owner {
            return p;
        }
    }
    panic!("no prompt found with owner {owner}");
}

fn stop_backend(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

fn stop_router(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

/// The routing acceptance bar: two backends behind the router serve a
/// pipelined mixed-owner load with every response byte-identical to the
/// single-backend sequential oracle, every forward accounted as either
/// hash-routed or spilled, and the inflight table empty afterwards.
#[test]
fn two_backend_routing_is_byte_identical_to_sequential_oracle() {
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    let (a1, h1) = start_backend(engine.fork());
    // Low spill depth on purpose: the concurrent burst pushes owners
    // over it, so both placement rules run — bytes must not care.
    let policy = RouterPolicy { spill_depth: 4, ..router_policy() };
    let router = router_over(&[a0, a1], policy, None);
    let (ra, rh) = start_router(&router);
    wait_all_healthy(ra, 2);

    let prompts: Vec<String> = (0..6usize)
        .map(|i| prompt_owned_by(&router, i % 2, &format!("mix{i}")))
        .collect();
    let want: Vec<String> = prompts.iter().map(|p| oracle(&engine, p, 10)).collect();

    let mut c = Client::connect(&ra.to_string()).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        c.send(
            &Json::obj()
                .set("id", i as u64)
                .set("prompt", p.as_str())
                .set("max_tokens", 10u64),
        )
        .unwrap();
    }
    for _ in 0..prompts.len() {
        let r = c.recv().unwrap();
        assert!(r.get("error").is_none(), "routed request failed: {r:?}");
        let id = r.get("id").and_then(Json::as_usize).expect("reply id");
        assert_eq!(
            r.get("text").and_then(Json::as_str),
            Some(want[id].as_str()),
            "request {id} must match the sequential oracle"
        );
    }

    let m = c.metrics().unwrap();
    let routed = m.get("routed").and_then(Json::as_usize).unwrap();
    let hash_routed = m.get("hash_routed").and_then(Json::as_usize).unwrap();
    let spilled = m.get("spilled").and_then(Json::as_usize).unwrap();
    assert_eq!(routed, prompts.len());
    assert_eq!(hash_routed + spilled, routed, "every forward is one rule or the other");
    assert_eq!(m.get("failovers").and_then(Json::as_usize), Some(0));
    assert_eq!(m.get("inflight").and_then(Json::as_usize), Some(0));

    drop(c);
    stop_router(ra, rh);
    stop_backend(a0, h0);
    stop_backend(a1, h1);
}

/// A backend killed mid-stream (after its first delivered delta) must
/// produce a clean `{"error":"backend lost","done":true}` final — never
/// a replayed retry, never silence — leave the router's inflight table
/// empty, keep its hash range served by the survivor, and leave *both*
/// engines' KV gauges exactly at the zero baseline (the dead link
/// cancels the orphaned sequence in the still-running engine process).
#[test]
fn mid_stream_backend_down_is_clean_error_with_gauges_at_baseline() {
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    let (a1, h1) = start_backend(engine.fork());
    // Kill backend 0's connection before its 2nd delivered data frame:
    // exactly one delta reaches the client first.
    let router = router_over(&[a0, a1], router_policy(), plan("backend_down:backend=0,reply=2"));
    let (ra, rh) = start_router(&router);
    wait_all_healthy(ra, 2);
    let p0 = prompt_owned_by(&router, 0, "doomed");
    let p1 = prompt_owned_by(&router, 1, "fine");

    let mut c = Client::connect(&ra.to_string()).unwrap();
    c.send(
        &Json::obj()
            .set("id", 7u64)
            .set("prompt", p0.as_str())
            .set("max_tokens", 8u64)
            .set("stream", true),
    )
    .unwrap();
    let mut deltas = 0;
    let fin = loop {
        let f = c.recv().unwrap();
        if f.get("done").and_then(Json::as_bool) == Some(true) {
            break f;
        }
        assert!(f.get("delta").is_some(), "unexpected frame: {f:?}");
        deltas += 1;
    };
    assert_eq!(deltas, 1, "exactly one delta precedes the injected death");
    assert_eq!(fin.get("error").and_then(Json::as_str), Some("backend lost"));
    assert_eq!(fin.get("id").and_then(Json::as_usize), Some(7));

    // No orphaned router state, and the loss is observable.
    let mut probe = Client::connect(&ra.to_string()).unwrap();
    wait_until("backend 0 marked down", || {
        backend_state(&probe.metrics().unwrap(), 0) == "down"
    });
    let m = probe.metrics().unwrap();
    assert_eq!(m.get("inflight").and_then(Json::as_usize), Some(0));
    assert_eq!(backend_state(&m, 1), "healthy");

    // The dead backend's range redistributes: both prompts keep serving
    // through the router, byte-identical.
    let r = c.generate(&p0, 8).unwrap();
    assert_eq!(r.get("text").and_then(Json::as_str), Some(oracle(&engine, &p0, 8).as_str()));
    let r = c.generate(&p1, 8).unwrap();
    assert_eq!(r.get("text").and_then(Json::as_str), Some(oracle(&engine, &p1, 8).as_str()));

    // Both engine processes are still running; the severed connection
    // cancelled backend 0's orphaned sequence. Gauges return to the
    // prefix-cache-off baseline: exactly zero.
    for (name, addr) in [("killed", a0), ("surviving", a1)] {
        let mut direct = Client::connect(&addr.to_string()).unwrap();
        wait_until("engine gauges at baseline", || {
            let m = direct.metrics().unwrap();
            m.get("slots_in_use").and_then(Json::as_usize) == Some(0)
                && m.get("cache_blocks_in_use").and_then(Json::as_usize) == Some(0)
        });
        let m = direct.metrics().unwrap();
        assert_eq!(
            m.get("queue_depth").and_then(Json::as_usize),
            Some(0),
            "{name} backend admission queue must be empty"
        );
    }

    drop(c);
    drop(probe);
    stop_router(ra, rh);
    stop_backend(a0, h0);
    stop_backend(a1, h1);
}

/// A connection that dies before the request's first streamed token is
/// retried exactly once on another healthy backend and the client sees
/// bytes identical to the oracle — the failover is unobservable. The
/// dropped backend then reconnects and reintegrates (probe-gated), and
/// its hash range returns to it.
#[test]
fn pre_first_token_failover_is_byte_identical_then_backend_reintegrates() {
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    let (a1, h1) = start_backend(engine.fork());
    // Drop backend 0's connection at the 1st forward: the write fails
    // before any frame flows, so the request redispatches unstarted.
    let router = router_over(&[a0, a1], router_policy(), plan("conn_drop:backend=0,fwd=1"));
    let (ra, rh) = start_router(&router);
    wait_all_healthy(ra, 2);
    let p0 = prompt_owned_by(&router, 0, "flaky");
    let want = oracle(&engine, &p0, 10);

    let mut c = Client::connect(&ra.to_string()).unwrap();
    let r = c.generate(&p0, 10).unwrap();
    assert!(r.get("error").is_none(), "failover must be transparent: {r:?}");
    assert_eq!(r.get("text").and_then(Json::as_str), Some(want.as_str()));

    let m = c.metrics().unwrap();
    assert_eq!(m.get("failovers").and_then(Json::as_usize), Some(1));
    assert_eq!(
        backend_obj(&m, 0).get("failovers").and_then(Json::as_usize),
        Some(1),
        "the failover is charged to the backend that lost the request"
    );
    assert_eq!(m.get("inflight").and_then(Json::as_usize), Some(0));

    // Unhealthy → reconnect → probe → healthy, all on the heartbeat.
    let mut probe = Client::connect(&ra.to_string()).unwrap();
    wait_until("backend 0 reintegration", || {
        backend_state(&probe.metrics().unwrap(), 0) == "healthy"
    });
    let before = backend_obj(&probe.metrics().unwrap(), 0)
        .get("hash_routed")
        .and_then(Json::as_usize)
        .unwrap();
    let r = c.generate(&p0, 10).unwrap();
    assert_eq!(r.get("text").and_then(Json::as_str), Some(want.as_str()));
    let after = backend_obj(&probe.metrics().unwrap(), 0)
        .get("hash_routed")
        .and_then(Json::as_usize)
        .unwrap();
    assert_eq!(after, before + 1, "the reintegrated owner takes its range back");

    drop(c);
    drop(probe);
    stop_router(ra, rh);
    stop_backend(a0, h0);
    stop_backend(a1, h1);
}

/// Graceful drain under pipelined load: `{"cmd":"drain","backend":0}`
/// racing a 12-request burst loses nothing — every reply arrives
/// byte-identical (finished on the draining backend, or shed there with
/// `"shutting down"` and transparently re-dispatched), the drained
/// backend's process exits, and its hash range moves to the survivor.
#[test]
fn drain_under_load_loses_zero_requests() {
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    let (a1, h1) = start_backend(engine.fork());
    let policy = RouterPolicy { heartbeat_ms: 10, ..router_policy() };
    let router = router_over(&[a0, a1], policy, None);
    let (ra, rh) = start_router(&router);
    wait_all_healthy(ra, 2);

    let prompts: Vec<String> = (0..12usize)
        .map(|i| prompt_owned_by(&router, i % 2, &format!("drain{i}")))
        .collect();
    let want: Vec<String> = prompts.iter().map(|p| oracle(&engine, p, 8)).collect();

    let mut c = Client::connect(&ra.to_string()).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        c.send(
            &Json::obj()
                .set("id", i as u64)
                .set("prompt", p.as_str())
                .set("max_tokens", 8u64),
        )
        .unwrap();
    }
    // Drain backend 0 from a second connection while the burst is in
    // flight — requests race the drain on every path there is.
    let mut admin = Client::connect(&ra.to_string()).unwrap();
    let ack = admin
        .call(&Json::obj().set("cmd", "drain").set("backend", 0u64))
        .unwrap();
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));

    for _ in 0..prompts.len() {
        let r = c.recv().unwrap();
        assert!(r.get("error").is_none(), "drain dropped a request: {r:?}");
        let id = r.get("id").and_then(Json::as_usize).expect("reply id");
        assert_eq!(
            r.get("text").and_then(Json::as_str),
            Some(want[id].as_str()),
            "request {id} must survive the drain byte-identically"
        );
    }

    // The drained backend finishes, exits, and is retired for good.
    wait_until("backend 0 drained down", || {
        backend_state(&admin.metrics().unwrap(), 0) == "down"
    });
    h0.join().unwrap();
    let m = admin.metrics().unwrap();
    assert_eq!(m.get("inflight").and_then(Json::as_usize), Some(0));

    // Its hash range now lands on the survivor.
    let p0 = prompt_owned_by(&router, 0, "after");
    let r = c.generate(&p0, 8).unwrap();
    assert!(r.get("error").is_none(), "post-drain request failed: {r:?}");
    assert_eq!(r.get("text").and_then(Json::as_str), Some(oracle(&engine, &p0, 8).as_str()));

    // Draining again (or an unknown index) is refused, not repeated.
    let ack = admin
        .call(&Json::obj().set("cmd", "drain").set("backend", 0u64))
        .unwrap();
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(false));
    let ack = admin
        .call(&Json::obj().set("cmd", "drain").set("backend", 9u64))
        .unwrap();
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(false));

    drop(c);
    drop(admin);
    stop_router(ra, rh);
    stop_backend(a1, h1);
}

/// The health checker alone: a backend that accepts TCP but never
/// answers a probe is marked unhealthy after `miss_threshold` beats
/// (`missed_heartbeats` counts them) and its hash range redistributes —
/// reintegration is probe-gated, so a connectable-but-silent backend
/// never becomes routable.
#[test]
fn silent_backend_is_marked_unhealthy_and_its_range_redistributes() {
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    // Backend 1 accepts connections and then says nothing, forever.
    let silent = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let a1 = silent.local_addr().unwrap();
    std::thread::spawn(move || {
        let mut held = Vec::new();
        for s in silent.incoming() {
            match s {
                Ok(s) => held.push(s),
                Err(_) => break,
            }
        }
    });
    let policy = RouterPolicy { miss_threshold: 2, ..router_policy() };
    let router = router_over(&[a0, a1], policy, None);
    let (ra, rh) = start_router(&router);

    let mut probe = Client::connect(&ra.to_string()).unwrap();
    wait_until("backend 0 healthy, backend 1 unhealthy with misses", || {
        let m = probe.metrics().unwrap();
        backend_state(&m, 0) == "healthy"
            && backend_state(&m, 1) == "unhealthy"
            && backend_obj(&m, 1)
                .get("missed_heartbeats")
                .and_then(Json::as_usize)
                .unwrap_or(0)
                >= 2
    });

    // Prompts owned by the silent backend serve on the healthy one.
    let p1 = prompt_owned_by(&router, 1, "silent");
    let mut c = Client::connect(&ra.to_string()).unwrap();
    let r = c.generate(&p1, 8).unwrap();
    assert!(r.get("error").is_none(), "redistributed request failed: {r:?}");
    assert_eq!(r.get("text").and_then(Json::as_str), Some(oracle(&engine, &p1, 8).as_str()));
    let m = probe.metrics().unwrap();
    assert!(
        backend_obj(&m, 0).get("hash_routed").and_then(Json::as_usize).unwrap() >= 1,
        "the silent backend's range is hash-routed to the survivor"
    );
    assert_eq!(
        backend_obj(&m, 1).get("routed").and_then(Json::as_usize),
        Some(0),
        "a never-probed backend never receives a request"
    );

    drop(c);
    drop(probe);
    stop_router(ra, rh);
    stop_backend(a0, h0);
}

/// The chaos acceptance bar over TCP with the CI router-fault leg's
/// spec (`backend_down:backend=0,reply=3`): under a pipelined mixed
/// stream/non-stream load, killing backend 0 before its 3rd delivered
/// frame, **every** request ends in exactly one final that is either
/// byte-identical to the sequential oracle (unstarted requests fail
/// over exactly) or the explicit `"backend lost"` error (started ones)
/// — zero silent drops, inflight table empty, surviving engine's gauges
/// exactly at baseline. When `SALR_FAULT` carries this exact spec (the
/// CI leg) the test goes through the production `Router::new` env path;
/// otherwise it arms the identical plan explicitly.
#[test]
fn router_chaos_under_env_fault_spec() {
    const SPEC: &str = "backend_down:backend=0,reply=3";
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    let (a1, h1) = start_backend(engine.fork());
    let env_armed = std::env::var("SALR_FAULT")
        .map(|s| s.trim() == SPEC)
        .unwrap_or(false);
    let addrs = [a0.to_string(), a1.to_string()];
    let router = if env_armed {
        Router::new(&addrs, router_policy())
    } else {
        Router::with_fault(&addrs, router_policy(), plan(SPEC))
    };
    let (ra, rh) = start_router(&router);
    wait_all_healthy(ra, 2);

    // Four streamed requests owned by the doomed backend, two plain
    // ones owned by the survivor.
    let prompts: Vec<(String, bool)> = (0..6usize)
        .map(|i| (prompt_owned_by(&router, usize::from(i >= 4), &format!("chaos{i}")), i < 4))
        .collect();
    let want: Vec<String> = prompts.iter().map(|(p, _)| oracle(&engine, p, 6)).collect();

    let mut c = Client::connect(&ra.to_string()).unwrap();
    for (i, (p, stream)) in prompts.iter().enumerate() {
        let mut msg = Json::obj()
            .set("id", i as u64)
            .set("prompt", p.as_str())
            .set("max_tokens", 6u64);
        if *stream {
            msg = msg.set("stream", true);
        }
        c.send(&msg).unwrap();
    }
    let mut finals: Vec<Option<Json>> = vec![None; prompts.len()];
    while finals.iter().any(Option::is_none) {
        let f = c.recv().unwrap();
        let id = f.get("id").and_then(Json::as_usize).expect("frame id");
        if f.get("delta").is_some() {
            continue;
        }
        assert!(finals[id].is_none(), "request {id} got two finals");
        finals[id] = Some(f);
    }
    let mut lost = 0;
    for (id, f) in finals.iter().enumerate() {
        let f = f.as_ref().unwrap();
        match f.get("error").and_then(Json::as_str) {
            None => assert_eq!(
                f.get("text").and_then(Json::as_str),
                Some(want[id].as_str()),
                "completed request {id} must match the sequential oracle"
            ),
            Some("backend lost") => lost += 1,
            Some(e) => panic!("request {id}: unexpected error {e:?}"),
        }
    }
    // Frames 1–2 delivered before the injected death started at least
    // one request; everything else either finished or failed over.
    assert!(lost >= 1, "the injected death must be observed mid-stream");
    assert!(lost <= 4, "only the doomed backend's streams may be lost");

    let mut probe = Client::connect(&ra.to_string()).unwrap();
    wait_until("backend 0 down after injected death", || {
        backend_state(&probe.metrics().unwrap(), 0) == "down"
    });
    let m = probe.metrics().unwrap();
    assert_eq!(m.get("inflight").and_then(Json::as_usize), Some(0), "no orphaned state");
    assert_eq!(backend_state(&m, 1), "healthy");

    // The whole hash range keeps serving, byte-identical, and the
    // surviving engine's gauges return exactly to baseline.
    for (p, _) in &prompts {
        let r = c.generate(p, 6).unwrap();
        assert_eq!(r.get("text").and_then(Json::as_str), Some(oracle(&engine, p, 6).as_str()));
    }
    let mut direct = Client::connect(&a1.to_string()).unwrap();
    wait_until("surviving gauges at baseline", || {
        let m = direct.metrics().unwrap();
        m.get("slots_in_use").and_then(Json::as_usize) == Some(0)
            && m.get("cache_blocks_in_use").and_then(Json::as_usize) == Some(0)
            && m.get("queue_depth").and_then(Json::as_usize) == Some(0)
    });

    drop(c);
    drop(probe);
    drop(direct);
    stop_router(ra, rh);
    stop_backend(a0, h0);
    stop_backend(a1, h1);
}
