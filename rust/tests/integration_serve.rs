//! Serving-layer integration: continuous batching with multiple engine
//! workers, chunked prefill and token streaming over TCP must return
//! byte-identical text to sequential single-worker whole-prompt serving,
//! keep running sequences decoding between a long prompt's prefill
//! chunks, admit requests into live batches mid-stream, complete
//! pipelined requests out of order (routed by id), and reject over-long
//! prompts with an error reply instead of panicking a worker.
//!
//! Prefix-cache acceptance: serving shared-prefix prompts with the
//! radix-tree cache enabled must be byte-identical to cache-off and to
//! the 1-worker sequential whole-prefill oracle at multiple thread
//! counts and block sizes, while the `prefix_hit_tokens` /
//! `prefill_tokens` counters prove prefill GEMM work was actually
//! skipped on the hit path. A reader that stops draining its stream must
//! never stall the engines (bounded per-connection reply queues).

use salr::infer::{Backend, Engine, EngineWeights};
use salr::model::ParamStore;
use salr::runtime::ModelCfg;
use salr::server::{serve, BatchPolicy, Batcher, Client, Request};
use salr::util::json::Json;
use salr::util::rng::Rng;
use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn test_engine() -> Engine {
    let cfg = ModelCfg {
        name: "serve-e2e".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq_len: 96,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 4,
        batch_size: 4,
        ctx_keep: 0.5,
    };
    let mut rng = Rng::new(7100);
    let base = ParamStore::init_base(&cfg, &mut rng);
    Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
}

fn start_server(engine: Engine, policy: BatchPolicy) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve(engine, "127.0.0.1:0", policy, Some(tx)).expect("serve");
    });
    (rx.recv().expect("server ready"), handle)
}

fn stop_server(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

/// N concurrent **streaming** clients against 2 continuous-batching
/// engine workers with a small prefill chunk: every response — and the
/// concatenation of its delta frames — byte-identical to the same prompts
/// served sequentially through a single worker with whole-prompt
/// (unchunked) prefill.
#[test]
fn chunked_streaming_multi_worker_matches_sequential_single_worker() {
    let engine = test_engine();
    let prompts: Vec<(String, usize)> = (0..9)
        .map(|i| (format!("Q: {}+{}=? A: ", 2 + i, 30 - i), 3 + (i % 4)))
        .collect();

    // Reference: one worker, whole-prompt prefill, requests submitted
    // strictly one at a time, no streaming.
    let (addr, handle) = start_server(
        engine.fork(),
        BatchPolicy {
            max_batch: 4,
            engine_workers: 1,
            prefill_chunk: 0,
            ..Default::default()
        },
    );
    let mut reference = Vec::new();
    {
        let mut c = Client::connect(&addr.to_string()).unwrap();
        for (p, n) in &prompts {
            let r = c.generate(p, *n).unwrap();
            reference.push(r.get("text").and_then(Json::as_str).unwrap().to_string());
        }
    }
    stop_server(addr, handle);

    // Under test: 2 engine workers, 3-token prefill chunks, 3 concurrent
    // streaming clients with 3 requests each.
    let (addr, handle) = start_server(
        engine.fork(),
        BatchPolicy {
            max_batch: 4,
            engine_workers: 2,
            prefill_chunk: 3,
            ..Default::default()
        },
    );
    let mut joins = Vec::new();
    for c in 0..3usize {
        let addr = addr.to_string();
        let chunk: Vec<(String, usize)> = prompts[c * 3..(c + 1) * 3].to_vec();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            chunk
                .iter()
                .map(|(p, n)| {
                    let mut streamed = String::new();
                    let r = client
                        .generate_stream(p, *n, |delta| streamed.push_str(delta))
                        .unwrap();
                    assert_eq!(r.get("done").and_then(Json::as_bool), Some(true));
                    let text = r.get("text").and_then(Json::as_str).unwrap().to_string();
                    assert_eq!(
                        streamed, text,
                        "delta frames must concatenate to the final text"
                    );
                    text
                })
                .collect::<Vec<String>>()
        }));
    }
    let mut got = Vec::new();
    for j in joins {
        got.extend(j.join().unwrap());
    }
    stop_server(addr, handle);
    assert_eq!(
        got, reference,
        "chunked+streamed multi-worker serving changed some response bytes"
    );
}

/// Long-prompt admission must not stall the running batch: while a long
/// prompt prefills in small chunks, the already-running sequence keeps
/// taking decode steps **between** the chunks. Asserted by sampling the
/// global prefill-chunk counter from the running sequence's stream
/// callback: its tokens arrive at many distinct chunk counts.
#[test]
fn running_sequences_keep_decoding_between_prefill_chunks() {
    let engine = test_engine();
    let batcher = Batcher::new(BatchPolicy {
        max_batch: 4,
        engine_workers: 1,
        prefill_chunk: 4,
        ..Default::default()
    });
    let workers = salr::server::spawn_engine_workers(&batcher, engine.fork());

    // Sequence X: short prompt, long generation, streamed; each delta
    // records how many prefill chunks (any sequence's) had run by then.
    let observations: Arc<Mutex<Vec<(String, u64)>>> = Arc::new(Mutex::new(Vec::new()));
    let obs = observations.clone();
    let bref = batcher.clone();
    let (tx, rx) = std::sync::mpsc::channel();
    let accepted = batcher.submit_stream_with(
        Request {
            id: 1,
            prompt: "Q: 2+2=? A: ".into(),
            max_tokens: 80,
            ..Default::default()
        },
        Box::new(move |delta| {
            let chunks = bref.metrics.prefill_chunks.load(Ordering::Relaxed);
            obs.lock().unwrap().push((delta.to_string(), chunks));
        }),
        Box::new(move |resp| {
            let _ = tx.send(resp);
        }),
    );
    assert!(accepted);
    let t0 = Instant::now();
    while batcher.metrics.decode_steps.load(Ordering::Relaxed) < 1 {
        assert!(t0.elapsed() < Duration::from_secs(30), "worker never started");
        std::thread::sleep(Duration::from_millis(1));
    }

    // Sequence Y: long prompt (48 tokens → 12 chunks of 4), one token.
    let y = batcher.submit(Request {
        id: 2,
        prompt: "y".repeat(48),
        max_tokens: 1,
        ..Default::default()
    });
    assert!(y.error.is_none(), "long-but-fitting prompt must be served");
    assert_eq!(y.tokens, 1);

    let x = rx.recv().unwrap();
    assert!(x.error.is_none());
    assert_eq!(x.tokens, 80);
    let obs = observations.lock().unwrap();
    let streamed: String = obs.iter().map(|(d, _)| d.as_str()).collect();
    assert_eq!(streamed, x.text);
    let mut distinct: Vec<u64> = obs.iter().map(|(_, c)| *c).collect();
    distinct.dedup();
    assert!(
        distinct.len() >= 3,
        "X must produce tokens at several distinct prefill-chunk counts \
         (saw {distinct:?}) — decode stalled behind Y's prefill"
    );
    // X's output is still byte-identical to serving it alone.
    let solo = engine.generate_batch(&[salr::data::tokenize("Q: 2+2=? A: ")], 80);
    assert_eq!(x.text, salr::data::detokenize(&solo[0]));

    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
}

/// A request arriving while a batch is mid-decode joins it (occupancy
/// grows, the metric records a mid-stream admission) instead of waiting
/// for the batch to drain — and the short request completes first even
/// though it was submitted second (out-of-order completion over one
/// pipelined connection). Runs with chunked prefill enabled, so the short
/// request's admission itself interleaves with the long one's decode.
#[test]
fn midstream_admission_and_out_of_order_completion_over_tcp() {
    let engine = test_engine();
    let (addr, handle) = start_server(
        engine,
        BatchPolicy {
            max_batch: 4,
            engine_workers: 1,
            prefill_chunk: 4,
            ..Default::default()
        },
    );
    let mut client = Client::connect(&addr.to_string()).unwrap();
    // Long request, pipelined (no blocking read).
    client
        .send(
            &Json::obj()
                .set("id", 100u64)
                .set("prompt", "Q: 12+31=? A: ")
                .set("max_tokens", 80u64),
        )
        .unwrap();
    // Wait until the worker is actually decoding it.
    let mut probe = Client::connect(&addr.to_string()).unwrap();
    let t0 = Instant::now();
    loop {
        let m = probe.metrics().unwrap();
        if m.get("decode_steps").and_then(Json::as_usize).unwrap_or(0) >= 1 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "worker never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    // Short request joins the live batch on the same connection.
    client
        .send(
            &Json::obj()
                .set("id", 101u64)
                .set("prompt", "Q: 1+1=? A: ")
                .set("max_tokens", 2u64),
        )
        .unwrap();
    // Completion order: the short request (id 101) must come back first.
    let first = client.recv().unwrap();
    assert_eq!(
        first.get("id").and_then(Json::as_usize),
        Some(101),
        "short request must finish before the long one (out-of-order completion)"
    );
    assert_eq!(first.get("tokens").and_then(Json::as_usize), Some(2));
    let second = client.recv().unwrap();
    assert_eq!(second.get("id").and_then(Json::as_usize), Some(100));
    assert_eq!(second.get("tokens").and_then(Json::as_usize), Some(80));

    let m = probe.metrics().unwrap();
    assert!(
        m.get("admitted_midstream").and_then(Json::as_usize).unwrap_or(0) >= 1,
        "second request must have joined a live batch"
    );
    assert!(
        m.get("max_occupancy").and_then(Json::as_usize).unwrap_or(0) >= 2,
        "occupancy must have grown without the batch draining"
    );
    drop(client);
    stop_server(addr, handle);
}

/// Serve `prompts` one at a time over one connection and return the
/// response texts plus the server's final metrics snapshot.
fn serve_sequentially(
    engine: Engine,
    policy: BatchPolicy,
    prompts: &[(String, usize)],
) -> (Vec<String>, Json) {
    let (addr, handle) = start_server(engine, policy);
    let mut texts = Vec::new();
    {
        let mut c = Client::connect(&addr.to_string()).unwrap();
        for (p, n) in prompts {
            let r = c.generate(p, *n).unwrap();
            assert!(r.get("error").is_none(), "request failed: {r:?}");
            texts.push(r.get("text").and_then(Json::as_str).unwrap().to_string());
        }
    }
    let mut probe = Client::connect(&addr.to_string()).unwrap();
    let metrics = probe.metrics().unwrap();
    drop(probe);
    stop_server(addr, handle);
    (texts, metrics)
}

/// The PR's acceptance bar: a batch of shared-prefix prompts served with
/// the prefix cache enabled is byte-identical to cache-off and to the
/// 1-worker sequential whole-prefill oracle — across 2 block sizes and 2
/// GEMM thread counts (and 1 vs 2 engine workers) — and the counters
/// prove the hit path actually skipped prefill forwards.
#[test]
fn shared_prefix_cache_byte_identity_and_gemm_skip() {
    let engine = test_engine(); // max_seq_len = 96
    let head = "SYSTEM: terse math assistant.\n"; // 30-token shared head
    assert_eq!(head.len(), 30);
    let prompts: Vec<(String, usize)> = (0..6)
        .map(|i| {
            (
                format!("{head}Q: {}+{}=? A: ", 2 + i % 3, 5 + i % 2),
                3 + (i % 3),
            )
        })
        .collect();
    let total_prompt_tokens: u64 = prompts.iter().map(|(p, _)| p.len() as u64).sum();

    // Oracle: 1 worker, 1 GEMM thread, whole-prompt prefill, cache off.
    let oracle_policy = BatchPolicy {
        max_batch: 4,
        engine_workers: 1,
        num_threads: 1,
        prefill_chunk: 0,
        kv_block_size: 16,
        prefix_cache: false,
        ..Default::default()
    };
    let (reference, cold_metrics) = serve_sequentially(engine.fork(), oracle_policy, &prompts);
    let cold_prefill = cold_metrics
        .get("prefill_tokens")
        .and_then(Json::as_usize)
        .unwrap() as u64;
    assert_eq!(cold_prefill, total_prompt_tokens, "cache-off prefills everything");
    assert_eq!(
        cold_metrics.get("prefix_hit_tokens").and_then(Json::as_usize),
        Some(0)
    );

    // Cache on, across (engine workers, GEMM threads, block size): every
    // configuration must reproduce the oracle bytes exactly.
    for &(workers, threads, block) in &[(1usize, 1usize, 4usize), (1, 2, 16), (2, 2, 4), (2, 1, 16)]
    {
        let policy = BatchPolicy {
            max_batch: 4,
            engine_workers: workers,
            num_threads: threads,
            prefill_chunk: 3,
            kv_block_size: block,
            prefix_cache: true,
            ..Default::default()
        };
        let (texts, metrics) = serve_sequentially(engine.fork(), policy, &prompts);
        assert_eq!(
            texts, reference,
            "workers={workers} threads={threads} block={block} changed response bytes"
        );
        let hits = metrics
            .get("prefix_hit_tokens")
            .and_then(Json::as_usize)
            .unwrap() as u64;
        let prefilled = metrics
            .get("prefill_tokens")
            .and_then(Json::as_usize)
            .unwrap() as u64;
        assert!(
            hits > 0,
            "workers={workers} block={block}: shared heads must hit the cache"
        );
        assert_eq!(
            prefilled + hits,
            total_prompt_tokens,
            "every admitted prompt token is either prefilled or a cache hit"
        );
        assert!(
            prefilled < cold_prefill,
            "the hit path must run strictly fewer prefill tokens than cold"
        );
        assert!(
            metrics
                .get("cache_blocks_in_use")
                .and_then(Json::as_usize)
                .unwrap()
                > 0,
            "retired chains must be retained for reuse"
        );
    }
}

/// Bounded per-connection reply queues: a client that submits a
/// streaming request and then never reads must not stall the engine
/// workers — the request runs to completion server-side and other
/// clients keep being served normally. (The overflow→disconnect policy
/// itself is unit-tested in `server::tcp`.)
#[test]
fn slow_stream_reader_does_not_stall_the_server() {
    let engine = test_engine();
    let (addr, handle) = start_server(
        engine,
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            stream_frame_cap: 4,
            ..Default::default()
        },
    );
    // The slow reader: submit a 30-token streamed generation, read nothing.
    let mut slow = Client::connect(&addr.to_string()).unwrap();
    slow.send(
        &Json::obj()
            .set("id", 7u64)
            .set("prompt", "Q: 9+9=? A: ")
            .set("max_tokens", 30u64)
            .set("stream", true),
    )
    .unwrap();
    // The engine must finish the request without anyone draining frames.
    let mut probe = Client::connect(&addr.to_string()).unwrap();
    let t0 = Instant::now();
    loop {
        let m = probe.metrics().unwrap();
        if m.get("requests").and_then(Json::as_usize).unwrap_or(0) >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "engine stalled behind an unread stream"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    // And a healthy client is served as usual.
    let mut healthy = Client::connect(&addr.to_string()).unwrap();
    let r = healthy.generate("Q: 1+2=? A: ", 3).unwrap();
    assert_eq!(r.get("tokens").and_then(Json::as_usize), Some(3));
    drop(slow);
    drop(healthy);
    stop_server(addr, handle);
}

/// KV-slot edge cases over the wire: a prompt longer than the slot
/// capacity gets an `error` reply (no worker panic, no leaked slot), and
/// the same connection immediately serves normal requests afterwards —
/// including a full `max_batch` of concurrent sequences, proving no slot
/// was lost to the failed admission.
#[test]
fn overlong_prompt_rejected_over_tcp_without_leaking_slots() {
    let engine = test_engine(); // max_seq_len = 96
    let (addr, handle) = start_server(
        engine,
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefill_chunk: 4,
            ..Default::default()
        },
    );
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let bad = client.generate(&"x".repeat(200), 4).unwrap();
    assert!(
        bad.get("error").and_then(Json::as_str).is_some(),
        "over-long prompt must produce an error reply, got {bad:?}"
    );
    // Both KV slots still work: two concurrent requests complete.
    client
        .send(
            &Json::obj()
                .set("id", 1u64)
                .set("prompt", "Q: 5+6=? A: ")
                .set("max_tokens", 3u64),
        )
        .unwrap();
    client
        .send(
            &Json::obj()
                .set("id", 2u64)
                .set("prompt", "Q: 7+8=? A: ")
                .set("max_tokens", 3u64),
        )
        .unwrap();
    let mut seen = Vec::new();
    for _ in 0..2 {
        let r = client.recv().unwrap();
        assert!(r.get("error").is_none(), "normal request failed: {r:?}");
        assert_eq!(r.get("tokens").and_then(Json::as_usize), Some(3));
        seen.push(r.get("id").and_then(Json::as_usize).unwrap());
    }
    seen.sort_unstable();
    assert_eq!(seen, vec![1, 2]);
    let m = client.metrics().unwrap();
    assert_eq!(m.get("rejected").and_then(Json::as_usize), Some(1));
    drop(client);
    stop_server(addr, handle);
}
