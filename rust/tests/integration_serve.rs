//! Serving-layer integration: continuous batching with multiple engine
//! workers over TCP must return byte-identical text to sequential
//! single-worker serving, admit requests into live batches mid-stream,
//! and complete pipelined requests out of order (routed by id).

use salr::infer::{Backend, Engine, EngineWeights};
use salr::model::ParamStore;
use salr::runtime::ModelCfg;
use salr::server::{serve, BatchPolicy, Client};
use salr::util::json::Json;
use salr::util::rng::Rng;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

fn test_engine() -> Engine {
    let cfg = ModelCfg {
        name: "serve-e2e".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq_len: 96,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 4,
        batch_size: 4,
        ctx_keep: 0.5,
    };
    let mut rng = Rng::new(7100);
    let base = ParamStore::init_base(&cfg, &mut rng);
    Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
}

fn start_server(engine: Engine, policy: BatchPolicy) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve(engine, "127.0.0.1:0", policy, Some(tx)).expect("serve");
    });
    (rx.recv().expect("server ready"), handle)
}

fn stop_server(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

/// N concurrent clients against 2 continuous-batching engine workers:
/// every response byte-identical to the same prompts served sequentially
/// through a single worker.
#[test]
fn multi_worker_continuous_matches_sequential_single_worker() {
    let engine = test_engine();
    let prompts: Vec<(String, usize)> = (0..9)
        .map(|i| (format!("Q: {}+{}=? A: ", 2 + i, 30 - i), 3 + (i % 4)))
        .collect();

    // Reference: one worker, requests submitted strictly one at a time.
    let (addr, handle) = start_server(
        engine.fork(),
        BatchPolicy {
            max_batch: 4,
            engine_workers: 1,
            ..Default::default()
        },
    );
    let mut reference = Vec::new();
    {
        let mut c = Client::connect(&addr.to_string()).unwrap();
        for (p, n) in &prompts {
            let r = c.generate(p, *n).unwrap();
            reference.push(r.get("text").and_then(Json::as_str).unwrap().to_string());
        }
    }
    stop_server(addr, handle);

    // Under test: 2 engine workers, 3 concurrent clients, 3 requests each.
    let (addr, handle) = start_server(
        engine.fork(),
        BatchPolicy {
            max_batch: 4,
            engine_workers: 2,
            ..Default::default()
        },
    );
    let mut joins = Vec::new();
    for c in 0..3usize {
        let addr = addr.to_string();
        let chunk: Vec<(String, usize)> = prompts[c * 3..(c + 1) * 3].to_vec();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            chunk
                .iter()
                .map(|(p, n)| {
                    let r = client.generate(p, *n).unwrap();
                    r.get("text").and_then(Json::as_str).unwrap().to_string()
                })
                .collect::<Vec<String>>()
        }));
    }
    let mut got = Vec::new();
    for j in joins {
        got.extend(j.join().unwrap());
    }
    stop_server(addr, handle);
    assert_eq!(
        got, reference,
        "continuous multi-worker serving changed some response bytes"
    );
}

/// A request arriving while a batch is mid-decode joins it (occupancy
/// grows, the metric records a mid-stream admission) instead of waiting
/// for the batch to drain — and the short request completes first even
/// though it was submitted second (out-of-order completion over one
/// pipelined connection).
#[test]
fn midstream_admission_and_out_of_order_completion_over_tcp() {
    let engine = test_engine();
    let (addr, handle) = start_server(
        engine,
        BatchPolicy {
            max_batch: 4,
            engine_workers: 1,
            ..Default::default()
        },
    );
    let mut client = Client::connect(&addr.to_string()).unwrap();
    // Long request, pipelined (no blocking read).
    client
        .send(
            &Json::obj()
                .set("id", 100u64)
                .set("prompt", "Q: 12+31=? A: ")
                .set("max_tokens", 80u64),
        )
        .unwrap();
    // Wait until the worker is actually decoding it.
    let mut probe = Client::connect(&addr.to_string()).unwrap();
    let t0 = Instant::now();
    loop {
        let m = probe.metrics().unwrap();
        if m.get("decode_steps").and_then(Json::as_usize).unwrap_or(0) >= 1 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "worker never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    // Short request joins the live batch on the same connection.
    client
        .send(
            &Json::obj()
                .set("id", 101u64)
                .set("prompt", "Q: 1+1=? A: ")
                .set("max_tokens", 2u64),
        )
        .unwrap();
    // Completion order: the short request (id 101) must come back first.
    let first = client.recv().unwrap();
    assert_eq!(
        first.get("id").and_then(Json::as_usize),
        Some(101),
        "short request must finish before the long one (out-of-order completion)"
    );
    assert_eq!(first.get("tokens").and_then(Json::as_usize), Some(2));
    let second = client.recv().unwrap();
    assert_eq!(second.get("id").and_then(Json::as_usize), Some(100));
    assert_eq!(second.get("tokens").and_then(Json::as_usize), Some(80));

    let m = probe.metrics().unwrap();
    assert!(
        m.get("admitted_midstream").and_then(Json::as_usize).unwrap_or(0) >= 1,
        "second request must have joined a live batch"
    );
    assert!(
        m.get("max_occupancy").and_then(Json::as_usize).unwrap_or(0) >= 2,
        "occupancy must have grown without the batch draining"
    );
    drop(client);
    stop_server(addr, handle);
}
