//! End-to-end smoke (placeholder; full pipeline lives in examples/finetune_math.rs).
#[test]
fn placeholder() {}
