//! End-to-end integration: prune 50% → truncated-SVD residual adapters →
//! bitmap encode → pipelined SALR engine vs the dense-merged reference
//! engine, plus correctness + determinism of the parallel GEMM and the
//! multi-worker pipeline across thread counts.

use salr::gemm::dense::gemm_f32_pool;
use salr::gemm::pipeline::{gemm_pipelined, salr_gemm_pipelined, PipelineConfig};
use salr::infer::{Backend, Engine, EngineWeights};
use salr::model::{ParamStore, WeightFormat};
use salr::prune::prune_global;
use salr::runtime::ModelCfg;
use salr::salr::build_salr;
use salr::sparse::BitmapMatrix;
use salr::tensor::{matmul, matmul_naive, max_abs_diff, Tensor};
use salr::util::pool::WorkerPool;
use salr::util::rng::Rng;

fn small_cfg() -> ModelCfg {
    ModelCfg {
        name: "e2e".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq_len: 24,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 8,
        batch_size: 2,
        ctx_keep: 0.5,
    }
}

/// The full SALR deployment path: prune the base model at 50%, build the
/// SVD residual adapters, bitmap-encode the pruned weights, and check that
/// the pipelined engine agrees with a dense engine running the same
/// weights merged — logits within tolerance, greedy generations equal,
/// and the sparse deployment strictly smaller.
#[test]
fn salr_pipeline_matches_dense_merged_end_to_end() {
    let cfg = small_cfg();
    let mut rng = Rng::new(900);
    let base = ParamStore::init_base(&cfg, &mut rng);
    // Prune 50% + truncated-SVD residual correction.
    let build = build_salr(&cfg, &base, 0.5, 7);
    let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
    for (name, t) in build.residual_adapters.iter() {
        adapters.insert(name, t.clone());
    }
    // Reference: the same pruned base + adapters, merged densely.
    let dense = Engine::new(
        EngineWeights::dense_merged(&cfg, &build.params, Some(&adapters)),
        Backend::Dense,
    );
    // Deployment: bitmap-encoded base + factored adapters through the
    // two-stage pipeline. Pinned to the exact bitmap format — this test
    // compares numerically against the dense merge, which the lossy nf4
    // leg of the CI matrix (SALR_WEIGHT_FORMAT=nf4) would not satisfy.
    let sparse = Engine::new(
        EngineWeights::salr_with_format(&cfg, &build.params, &adapters, None, WeightFormat::Bitmap),
        Backend::BitmapPipelined(PipelineConfig::default()),
    );
    let tokens: Vec<i32> = vec![3, 11, 19, 27, 35, 43];
    let a = dense.full_logits(&tokens);
    let b = sparse.full_logits(&tokens);
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-2, "pipelined vs dense-merged logits diff {diff}");
    let ga = dense.generate_batch(&[tokens.clone()], 4);
    let gb = sparse.generate_batch(&[tokens], 4);
    assert_eq!(ga, gb, "greedy generations must agree");
    // (Storage compression is asserted at realistic layer sizes in the
    // engine unit tests — at d_model=32 the adapters dominate.)
}

/// Parallel dense GEMM: matches the naive reference at several thread
/// counts, is bitwise identical across thread counts, and is bit-stable
/// across repeated runs.
#[test]
fn parallel_gemm_correct_and_deterministic() {
    let mut rng = Rng::new(901);
    for &(m, k, n) in &[(65usize, 257usize, 130usize), (256, 256, 256), (100, 300, 50)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let want = matmul_naive(&a, &b);
        let mut reference: Option<Vec<f32>> = None;
        for &t in &[1usize, 2, 4] {
            let pool = WorkerPool::with_threads(t);
            let mut c = vec![0.0f32; m * n];
            gemm_f32_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
            let ct = Tensor::from_vec(&[m, n], c.clone());
            let diff = max_abs_diff(&ct, &want);
            assert!(diff < 1e-2 * (k as f32).sqrt(), "({m},{k},{n}) t={t} diff={diff}");
            match &reference {
                None => reference = Some(c),
                Some(r) => assert_eq!(&c, r, "({m},{k},{n}) t={t} changed the bits"),
            }
        }
        let pool = WorkerPool::with_threads(4);
        let first = reference.unwrap();
        for _ in 0..5 {
            let mut c = vec![0.0f32; m * n];
            gemm_f32_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
            assert_eq!(c, first, "({m},{k},{n}) repeated run changed the bits");
        }
    }
}

/// Multi-worker pipelined sparse GEMM (with and without fused adapters):
/// matches the naive reference and is bitwise deterministic across runs
/// and thread counts.
#[test]
fn pipelined_gemm_correct_and_deterministic_across_threads() {
    let mut rng = Rng::new(902);
    let (m, k, n, r) = (8usize, 300usize, 96usize, 16usize);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
    prune_global(&mut [&mut w], 0.5);
    let bm = BitmapMatrix::encode(&w);
    let a = Tensor::randn(&[k, r], 0.1, &mut rng);
    let b = Tensor::randn(&[r, n], 0.1, &mut rng);
    let want_base = matmul_naive(&x, &w);
    let want_salr = {
        let update = matmul(&matmul(&x, &a), &b);
        salr::tensor::add(&want_base, &update)
    };
    let mut base_ref: Option<Vec<f32>> = None;
    let mut salr_ref: Option<Vec<f32>> = None;
    for &t in &[1usize, 2, 4] {
        let cfg = PipelineConfig {
            panel_k: 32,
            ring_depth: 3,
            num_threads: t,
        };
        let mut c = vec![0.0f32; m * n];
        gemm_pipelined(x.data(), &bm, &mut c, m, cfg);
        let ct = Tensor::from_vec(&[m, n], c.clone());
        assert!(max_abs_diff(&ct, &want_base) < 1e-3, "bitmap t={t}");
        for _ in 0..5 {
            let mut c2 = vec![0.0f32; m * n];
            gemm_pipelined(x.data(), &bm, &mut c2, m, cfg);
            assert_eq!(c2, c, "bitmap t={t} nondeterministic");
        }
        match &base_ref {
            None => base_ref = Some(c),
            Some(rf) => assert_eq!(&c, rf, "bitmap t={t} differs from t=1"),
        }

        let mut cs = vec![0.0f32; m * n];
        salr_gemm_pipelined(x.data(), &bm, a.data(), b.data(), r, &mut cs, m, cfg);
        let cst = Tensor::from_vec(&[m, n], cs.clone());
        assert!(max_abs_diff(&cst, &want_salr) < 1e-2, "salr t={t}");
        for _ in 0..5 {
            let mut cs2 = vec![0.0f32; m * n];
            salr_gemm_pipelined(x.data(), &bm, a.data(), b.data(), r, &mut cs2, m, cfg);
            assert_eq!(cs2, cs, "salr t={t} nondeterministic");
        }
        match &salr_ref {
            None => salr_ref = Some(cs),
            Some(rf) => assert_eq!(&cs, rf, "salr t={t} differs from t=1"),
        }
    }
}
