//! Cross-layer integration: the rust coordinator (L3), the AOT-lowered JAX
//! model (L2) and the Pallas kernel (L1) must agree numerically.
//!
//! Requires `make artifacts` (skips cleanly when artifacts are absent).

use salr::data::tokenize;
use salr::infer::{Backend, Engine, EngineWeights};
use salr::model::{ParamStore, WeightFormat};
use salr::runtime::{Runtime, Value};
use salr::salr::build_salr;
use salr::sparse::BitmapMatrix;
use salr::tensor::{max_abs_diff, Tensor};
use salr::util::rng::Rng;
use std::collections::HashMap;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

/// L2 vs L3: the HLO eval artifact and the native rust engine must produce
/// the same logits for the same parameters.
#[test]
fn hlo_eval_matches_native_engine() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let mut rng = Rng::new(900);
    let base = ParamStore::init_base(&cfg, &mut rng);
    // Nonzero adapters so the LoRA path is actually exercised.
    let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, false);
    for (_, t) in adapters.iter_mut() {
        let mut r2 = Rng::new(7);
        r2.fill_normal(t.data_mut(), 0.05);
    }

    let exec = rt.executor("eval_lora_tiny").unwrap();
    let mut bindings: HashMap<&str, Value> = HashMap::new();
    let mut names: Vec<String> = Vec::new();
    for io in &exec.spec().inputs {
        names.push(io.name.clone());
    }
    let tokens: Vec<i32> = (0..cfg.batch_size * cfg.max_seq_len)
        .map(|i| ((i * 37) % 200 + 32) as i32)
        .collect();
    for name in &names {
        if let Some(key) = name.strip_prefix("frozen:") {
            bindings.insert(name, Value::F32(base.get(key).unwrap().data().to_vec()));
        } else if let Some(key) = name.strip_prefix("train:") {
            bindings.insert(name, Value::F32(adapters.get(key).unwrap().data().to_vec()));
        } else if name == "tokens" {
            bindings.insert(name, Value::I32(tokens.clone()));
        }
    }
    let outputs = exec.run(&bindings).expect("hlo eval");
    let hlo_logits = &outputs[0]; // [B, S, V]

    let engine = Engine::new(
        EngineWeights::dense_merged(&cfg, &base, Some(&adapters)),
        Backend::Dense,
    );
    for b in 0..cfg.batch_size.min(2) {
        let seq = &tokens[b * cfg.max_seq_len..(b + 1) * cfg.max_seq_len];
        let native = engine.full_logits(seq);
        // Slice the HLO logits for this batch row.
        let v = cfg.vocab_size;
        let start = b * cfg.max_seq_len * v;
        let hlo_row = Tensor::from_vec(
            &[cfg.max_seq_len, v],
            hlo_logits.data()[start..start + cfg.max_seq_len * v].to_vec(),
        );
        let diff = max_abs_diff(&native, &hlo_row);
        assert!(
            diff < 5e-3,
            "L2 (HLO) and L3 (native) disagree: max|Δlogit| = {diff}"
        );
    }
}

/// L1 vs L3: the AOT-lowered Pallas SALR kernel and the rust two-stage
/// pipeline must compute the same SALR linear.
#[test]
fn pallas_kernel_artifact_matches_rust_pipeline() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let exec = rt.executor("salr_kernel_pallas_tiny").unwrap();
    let spec = exec.spec();
    // Shapes from the manifest.
    let d_in = cfg.d_model;
    let d_out = cfg.d_ff;
    let m = cfg.batch_size * cfg.max_seq_len;
    let rank_total = cfg.rank + cfg.residual_rank;
    let nnz_pad = spec
        .inputs
        .iter()
        .find(|i| i.name == "values")
        .unwrap()
        .elems();
    let wpr = d_out.div_ceil(32);

    let mut rng = Rng::new(901);
    let mut w = Tensor::randn(&[d_in, d_out], 1.0, &mut rng);
    salr::prune::prune_global(&mut [&mut w], 0.5);
    let bm = BitmapMatrix::encode(&w);
    let x = Tensor::randn(&[m, d_in], 1.0, &mut rng);
    let a_cat = Tensor::randn(&[d_in, rank_total], 0.1, &mut rng);
    let b_cat = Tensor::randn(&[rank_total, d_out], 0.1, &mut rng);

    // Convert the u8 byte masks into the kernel's u32 words (little-endian
    // bit order matches: bit t of word w = column 32w + t).
    let bpr = bm.bytes_per_row();
    let mut words = vec![0u32; d_in * wpr];
    for i in 0..d_in {
        for b in 0..bpr {
            let byte = bm.masks()[i * bpr + b] as u32;
            words[i * wpr + b / 4] |= byte << (8 * (b % 4));
        }
    }
    let mut values = bm.values().to_vec();
    values.resize(nnz_pad, 0.0);
    let offsets: Vec<i32> = bm.row_offsets()[..d_in].iter().map(|&o| o as i32).collect();

    let mut bindings: HashMap<&str, Value> = HashMap::new();
    bindings.insert("x", Value::F32(x.data().to_vec()));
    bindings.insert("mask_words", Value::U32(words));
    bindings.insert("values", Value::F32(values));
    bindings.insert("row_offsets", Value::I32(offsets));
    bindings.insert("a_cat", Value::F32(a_cat.data().to_vec()));
    bindings.insert("b_cat", Value::F32(b_cat.data().to_vec()));
    let out = exec.run(&bindings).expect("pallas kernel artifact");
    let kernel_y = &out[0];

    // Rust pipeline reference.
    let mut rust_y = vec![0.0f32; m * d_out];
    salr::gemm::pipeline::salr_gemm_pipelined(
        x.data(),
        &bm,
        a_cat.data(),
        b_cat.data(),
        rank_total,
        &mut rust_y,
        m,
        Default::default(),
    );
    let rust_y = Tensor::from_vec(&[m, d_out], rust_y);
    let diff = max_abs_diff(kernel_y, &rust_y);
    assert!(
        diff < 2e-2,
        "L1 (Pallas) and L3 (rust pipeline) disagree: max|Δ| = {diff}"
    );
}

/// The losa eval artifact honors masks (sanity of the mask plumbing).
#[test]
fn losa_eval_artifact_masks_weights() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let mut rng = Rng::new(902);
    let base = ParamStore::init_base(&cfg, &mut rng);
    let adapters = ParamStore::init_adapters(&cfg, &mut rng, false);
    let exec = rt.executor("eval_losa_tiny").unwrap();
    let tokens: Vec<i32> = (0..cfg.batch_size * cfg.max_seq_len)
        .map(|i| ((i * 13) % 200 + 32) as i32)
        .collect();

    let run_with_masks = |fill: f32| -> Tensor {
        let mut bindings: HashMap<&str, Value> = HashMap::new();
        let names: Vec<String> = exec.spec().inputs.iter().map(|i| i.name.clone()).collect();
        for name in &names {
            if let Some(key) = name.strip_prefix("frozen:") {
                if key.ends_with(".mask") {
                    let lin = key.split('.').nth(1).unwrap();
                    let (di, dо) = cfg.linear_shape(lin);
                    bindings.insert(name, Value::F32(vec![fill; di * dо]));
                } else {
                    bindings.insert(name, Value::F32(base.get(key).unwrap().data().to_vec()));
                }
            } else if let Some(key) = name.strip_prefix("train:") {
                bindings
                    .insert(name, Value::F32(adapters.get(key).unwrap().data().to_vec()));
            } else if name == "tokens" {
                bindings.insert(name, Value::I32(tokens.clone()));
            }
        }
        exec.run(&bindings).unwrap().remove(0)
    };
    let ones = run_with_masks(1.0);
    let zeros = run_with_masks(0.0);
    let diff = max_abs_diff(&ones, &zeros);
    assert!(diff > 1e-3, "masks had no effect (diff={diff})");
}

/// SALR build → HLO salr eval == native SALR engine (residual included).
#[test]
fn salr_eval_artifact_matches_native_salr_engine() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let mut rng = Rng::new(903);
    let base = ParamStore::init_base(&cfg, &mut rng);
    let build = build_salr(&cfg, &base, 0.5, 77);
    let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
    for (k, v) in build.residual_adapters.iter() {
        adapters.insert(k, v.clone());
    }
    let exec = rt.executor("eval_salr_tiny").unwrap();
    let tokens: Vec<i32> = (0..cfg.batch_size * cfg.max_seq_len)
        .map(|i| ((i * 41) % 200 + 32) as i32)
        .collect();
    let mut bindings: HashMap<&str, Value> = HashMap::new();
    let names: Vec<String> = exec.spec().inputs.iter().map(|i| i.name.clone()).collect();
    for name in &names {
        if let Some(key) = name.strip_prefix("frozen:") {
            bindings.insert(
                name,
                Value::F32(build.params.get(key).unwrap().data().to_vec()),
            );
        } else if let Some(key) = name.strip_prefix("train:") {
            bindings.insert(name, Value::F32(adapters.get(key).unwrap().data().to_vec()));
        } else if name == "tokens" {
            bindings.insert(name, Value::I32(tokens.clone()));
        }
    }
    let hlo = exec.run(&bindings).unwrap().remove(0);

    // Pinned to the exact bitmap format: the HLO reference runs dense
    // math, so the lossy nf4 CI leg would not meet the tolerance.
    let engine = Engine::new(
        EngineWeights::salr_with_format(&cfg, &build.params, &adapters, None, WeightFormat::Bitmap),
        Backend::BitmapPipelined(Default::default()),
    );
    let seq = &tokens[..cfg.max_seq_len];
    let native = engine.full_logits(seq);
    let v = cfg.vocab_size;
    let hlo_row = Tensor::from_vec(
        &[cfg.max_seq_len, v],
        hlo.data()[..cfg.max_seq_len * v].to_vec(),
    );
    let diff = max_abs_diff(&native, &hlo_row);
    assert!(diff < 5e-3, "SALR L2 vs L3 disagree: {diff}");
}

/// Generation path sanity over tokens from the tokenizer.
#[test]
fn tokenized_generation_roundtrip() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest().config("tiny").unwrap().clone();
    let mut rng = Rng::new(904);
    let base = ParamStore::init_base(&cfg, &mut rng);
    let engine = Engine::new(
        EngineWeights::dense_merged(&cfg, &base, None),
        Backend::Dense,
    );
    let prompt = tokenize("Q: 1+1=? A: ");
    let out = engine.generate_batch(&[prompt], 4);
    assert_eq!(out[0].len(), 4);
    for &t in &out[0] {
        assert!((0..256).contains(&t));
    }
}
