//! Fault-tolerance integration: the serving tier under injected panics,
//! delays, cancellation, deadlines, load shedding and dead connections.
//!
//! Every failure here is **deterministic**: panics and stalls are keyed
//! on per-worker op counters via [`FaultPlan`] (never wall-clock), shed
//! tests fill the bounded queue before any worker exists, admission-time
//! deadline tests use `timeout_ms: 0`, and mid-flight cancel/timeout
//! tests ride a `delay:` fault whose stall dwarfs every other latency in
//! the test. The acceptance bars (ISSUE 6): a panicking worker fails its
//! in-flight requests with error replies and is respawned while
//! survivors stay byte-identical to the fault-free oracle, and every
//! abnormal exit — cancelled, timed out, shed, panic-failed — returns
//! the KV accounting gauges exactly to their pre-run values.
//!
//! CI runs this file twice: once in the ordinary matrix (each test arms
//! its own explicit [`Batcher::with_fault`] plan) and once in the fault
//! leg with `SALR_FAULT=panic:worker=1,decode_step=4`, where
//! [`tcp_supervision_under_panic_fault_spec`] additionally goes through
//! the production `serve` → `Batcher::new` → env-parsing path.

use salr::data::{detokenize, tokenize};
use salr::infer::{Backend, Engine, EngineWeights, SpecMode};
use salr::model::ParamStore;
use salr::runtime::ModelCfg;
use salr::server::{
    serve, serve_on, spawn_engine_workers, BatchPolicy, Batcher, CancelToken, Client, Request,
    Response,
};
use salr::util::fault::FaultPlan;
use salr::util::json::Json;
use salr::util::rng::Rng;
use std::io::BufRead;
use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn test_engine() -> Engine {
    let cfg = ModelCfg {
        name: "fault-e2e".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        max_seq_len: 96,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 4,
        batch_size: 2,
        ctx_keep: 0.5,
    };
    let mut rng = Rng::new(500);
    let base = ParamStore::init_base(&cfg, &mut rng);
    Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
}

/// The fault-free reference bytes for one prompt.
fn oracle(engine: &Engine, prompt: &str, max_tokens: usize) -> String {
    let out = engine.generate_batch(&[tokenize(prompt)], max_tokens);
    detokenize(&out[0])
}

fn plan(spec: &str) -> Option<FaultPlan> {
    Some(FaultPlan::parse(spec).expect("test fault spec"))
}

/// Spin until `cond` holds (the gauges publish once per scheduler
/// iteration, a hair after the reply callback fires).
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// A TCP server over an explicit batcher (so tests control the fault
/// plan regardless of `SALR_FAULT` in the environment).
fn start_server_on(
    engine: Engine,
    batcher: Arc<Batcher>,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve_on(engine, "127.0.0.1:0", batcher, Some(tx)).expect("serve");
    });
    (rx.recv().expect("server ready"), handle)
}

fn stop_server(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

/// The supervision acceptance bar: with two engine workers and an
/// injected panic before whichever worker first reaches its 4th decode
/// step, (1) the panicking worker's in-flight requests get error
/// replies, (2) every surviving response is byte-identical to the
/// fault-free sequential oracle, (3) `worker_restarts == 1`, and (4) the
/// respawned worker keeps serving — same bytes — with zero leaked KV.
#[test]
fn supervisor_respawns_after_injected_panic_and_survivors_match_oracle() {
    let engine = test_engine();
    let prompts: Vec<String> = (0..4).map(|i| format!("Q: {}+{}=? A: ", 3 + i, 20 - i)).collect();
    let want: Vec<String> = prompts.iter().map(|p| oracle(&engine, p, 12)).collect();

    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 2,
            prefill_chunk: 4,
            prefix_cache: false,
            ..Default::default()
        },
        plan("panic:decode_step=4"),
    );
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let mut joins = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let b = batcher.clone();
        let p = p.clone();
        joins.push(std::thread::spawn(move || {
            b.submit(Request {
                id: i as u64,
                prompt: p,
                max_tokens: 12,
                ..Default::default()
            })
        }));
    }
    let responses: Vec<Response> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // The fault fires exactly once, on a worker holding 1..=max_batch
    // live sequences — those fail, nothing else does.
    let failed: Vec<&Response> = responses.iter().filter(|r| r.error.is_some()).collect();
    assert!(
        (1..=2).contains(&failed.len()),
        "only the panicking worker's in-flight requests may fail (got {})",
        failed.len()
    );
    for r in &failed {
        let err = r.error.as_deref().unwrap();
        assert!(err.contains("panicked"), "unexpected failure: {err}");
        assert_eq!(r.tokens, 0, "failed requests discard partial output");
    }
    for r in responses.iter().filter(|r| r.error.is_none()) {
        assert_eq!(
            r.text, want[r.id as usize],
            "survivor bytes must match the fault-free oracle"
        );
    }
    assert_eq!(batcher.metrics.worker_restarts.load(Ordering::Relaxed), 1);

    // The respawned worker serves every prompt again, byte-identical.
    for (i, p) in prompts.iter().enumerate() {
        let r = batcher.submit(Request {
            id: 100 + i as u64,
            prompt: p.clone(),
            max_tokens: 12,
            ..Default::default()
        });
        assert!(r.error.is_none(), "post-respawn request failed: {:?}", r.error);
        assert_eq!(r.text, want[i]);
    }
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    for (w, m) in batcher.worker_metrics().iter().enumerate() {
        assert_eq!(m.slots_in_use, 0, "worker {w} leaked a KV slot");
        assert_eq!(m.cache_blocks_in_use, 0, "worker {w} leaked KV blocks");
    }
}

/// The leak acceptance bar: one run mixing every abnormal exit — shed at
/// the bounded queue, failed by a worker panic, cancelled mid-stream,
/// expired at admission — must leave the KV gauges at zero and every
/// slot reusable (a full `max_batch × workers` load succeeds after).
#[test]
fn mixed_failures_shed_cancel_timeout_panic_leave_no_kv_leaks() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 2,
            max_queue_depth: 3,
            prefix_cache: false,
            ..Default::default()
        },
        plan("panic:decode_step=6"),
    );

    // Overfill the bounded queue before any worker exists: 3 queue, 2 shed.
    let (tx, rx) = mpsc::channel();
    for i in 0..5u64 {
        let tx = tx.clone();
        batcher.submit_with(
            Request {
                id: i,
                prompt: format!("Q: {i}+3=? A: "),
                max_tokens: 40,
                ..Default::default()
            },
            Box::new(move |r| {
                let _ = tx.send(r);
            }),
        );
    }
    let shed: Vec<Response> = rx.try_iter().collect();
    assert_eq!(shed.len(), 2, "overflow replies fire synchronously");
    for r in &shed {
        assert_eq!(r.error.as_deref(), Some("overloaded"));
    }
    assert_eq!(batcher.metrics.shed.load(Ordering::Relaxed), 2);

    // Workers drain the 3 queued requests; the injected panic fails the
    // first worker to reach decode step 6 mid-flight.
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let mut panicked = 0;
    for _ in 0..3 {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("queued reply");
        match &r.error {
            Some(e) => {
                assert!(e.contains("panicked"), "unexpected error: {e}");
                panicked += 1;
            }
            None => assert_eq!(r.tokens, 40),
        }
    }
    assert!((1..=2).contains(&panicked), "the panic fails 1..=max_batch requests");
    assert_eq!(batcher.metrics.worker_restarts.load(Ordering::Relaxed), 1);

    // Cancel mid-stream: the stream callback latches the request's own
    // token at its first delta — retired "cancelled" at the next boundary.
    let token = CancelToken::new();
    let latch = token.clone();
    let (ctx, crx) = mpsc::channel();
    batcher.submit_stream_with(
        Request {
            id: 10,
            prompt: "Q: 5+5=? A: ".into(),
            max_tokens: 40,
            timeout_ms: None,
            cancel: Some(token),
        },
        Box::new(move |_delta| latch.cancel()),
        Box::new(move |r| {
            let _ = ctx.send(r);
        }),
    );
    let r = crx.recv_timeout(Duration::from_secs(30)).expect("cancel reply");
    assert_eq!(r.error.as_deref(), Some("cancelled"));

    // Deadline already expired at admission: retired "timeout", no slot.
    let r = batcher.submit(Request {
        id: 11,
        prompt: "Q: 6+6=? A: ".into(),
        max_tokens: 40,
        timeout_ms: Some(0),
        ..Default::default()
    });
    assert_eq!(r.error.as_deref(), Some("timeout"));
    assert_eq!(batcher.metrics.cancelled.load(Ordering::Relaxed), 1);
    assert_eq!(batcher.metrics.timed_out.load(Ordering::Relaxed), 1);

    // Every slot survived all of the above: a full max_batch × workers
    // load runs concurrently.
    let mut joins = Vec::new();
    for i in 0..4u64 {
        let b = batcher.clone();
        joins.push(std::thread::spawn(move || {
            b.submit(Request {
                id: 20 + i,
                prompt: format!("Q: {i}+9=? A: "),
                max_tokens: 3,
                ..Default::default()
            })
        }));
    }
    for j in joins {
        let r = j.join().unwrap();
        assert!(r.error.is_none(), "post-fault capacity check failed: {:?}", r.error);
        assert_eq!(r.tokens, 3);
    }

    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    for (w, m) in batcher.worker_metrics().iter().enumerate() {
        assert_eq!(m.slots_in_use, 0, "worker {w} leaked a KV slot");
        assert_eq!(m.cache_blocks_in_use, 0, "worker {w} leaked KV blocks");
    }
}

/// With the prefix cache on, abnormal exits must return the block gauge
/// **exactly** to the retained-chain baseline: a cancelled request's
/// shared prefix blocks refcount back down, its private decode blocks
/// free outright, and a resubmission reproduces the warmup bytes.
#[test]
fn prefix_cache_accounting_returns_to_baseline_after_cancel_and_timeout() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefill_chunk: 4,
            kv_block_size: 4,
            prefix_cache: true,
            ..Default::default()
        },
        None,
    );
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let prompt = "SYSTEM: terse.\nQ: 4+4=? A: ";

    // Warmup registers the prompt's chain in the prefix cache.
    let warm = batcher.submit(Request {
        id: 1,
        prompt: prompt.into(),
        max_tokens: 4,
        ..Default::default()
    });
    assert!(warm.error.is_none());
    wait_until("warmup gauges to publish", || {
        batcher.worker_metrics()[0].slots_in_use == 0
    });
    let baseline = batcher.worker_metrics()[0].cache_blocks_in_use;
    assert!(baseline > 0, "the retired chain must be retained for reuse");

    // Same prompt, cancelled at its first streamed token: its prefix
    // attach and decode blocks must all come back.
    let token = CancelToken::new();
    let latch = token.clone();
    let (tx, rx) = mpsc::channel();
    batcher.submit_stream_with(
        Request {
            id: 2,
            prompt: prompt.into(),
            max_tokens: 40,
            timeout_ms: None,
            cancel: Some(token),
        },
        Box::new(move |_delta| latch.cancel()),
        Box::new(move |r| {
            let _ = tx.send(r);
        }),
    );
    let r = rx.recv_timeout(Duration::from_secs(30)).expect("cancel reply");
    assert_eq!(r.error.as_deref(), Some("cancelled"));

    // Same prompt, dead on arrival: the admission-time deadline check
    // never touches the pool.
    let r = batcher.submit(Request {
        id: 3,
        prompt: prompt.into(),
        max_tokens: 4,
        timeout_ms: Some(0),
        ..Default::default()
    });
    assert_eq!(r.error.as_deref(), Some("timeout"));

    // The cache still serves the head, byte-identically.
    let again = batcher.submit(Request {
        id: 4,
        prompt: prompt.into(),
        max_tokens: 4,
        ..Default::default()
    });
    assert!(again.error.is_none());
    assert_eq!(again.text, warm.text, "post-failure resubmission changed bytes");
    wait_until("final gauges to publish", || {
        batcher.worker_metrics()[0].slots_in_use == 0
    });
    assert_eq!(
        batcher.worker_metrics()[0].cache_blocks_in_use, baseline,
        "abnormal exits must return block accounting exactly to baseline"
    );

    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
}

/// A deadline that expires mid-generation (forced by an injected decode
/// stall much longer than the deadline) retires the request with
/// `"timeout"` at the next step boundary; the worker then serves the
/// next request normally.
#[test]
fn deadline_expires_mid_generation_under_injected_delay() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefix_cache: false,
            ..Default::default()
        },
        // Stall 400 ms before the 2nd decode step: the 100 ms deadline
        // expires during the stall however slow the machine is, and the
        // budget (20 tokens) guarantees the request is still live.
        plan("delay:decode_step=2,ms=400"),
    );
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let r = batcher.submit(Request {
        id: 1,
        prompt: "Q: 6+7=? A: ".into(),
        max_tokens: 20,
        timeout_ms: Some(100),
        ..Default::default()
    });
    assert_eq!(r.error.as_deref(), Some("timeout"));
    assert_eq!(r.tokens, 0, "partial output is discarded");
    assert_eq!(batcher.metrics.timed_out.load(Ordering::Relaxed), 1);

    let ok = batcher.submit(Request {
        id: 2,
        prompt: "Q: 1+2=? A: ".into(),
        max_tokens: 3,
        ..Default::default()
    });
    assert!(ok.error.is_none());
    assert_eq!(ok.tokens, 3);

    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    let m = &batcher.worker_metrics()[0];
    assert_eq!((m.slots_in_use, m.cache_blocks_in_use), (0, 0));
}

/// `--default-deadline-ms` applies to requests that set no timeout of
/// their own, and a per-request `timeout_ms` overrides it in either
/// direction — here a generous override rides out a stall the default
/// would have timed out on, completing byte-identically to the oracle.
#[test]
fn policy_default_deadline_applies_and_request_override_wins() {
    let engine = test_engine();
    let policy = BatchPolicy {
        max_batch: 2,
        engine_workers: 1,
        prefix_cache: false,
        default_deadline_ms: 100,
        ..Default::default()
    };

    // No per-request timeout: the policy default times it out mid-stall.
    let batcher = Batcher::with_fault(policy, plan("delay:decode_step=2,ms=400"));
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let r = batcher.submit(Request {
        id: 1,
        prompt: "Q: 8+3=? A: ".into(),
        max_tokens: 20,
        ..Default::default()
    });
    assert_eq!(r.error.as_deref(), Some("timeout"));
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }

    // Explicit override far above the default: the same stall is ridden
    // out and the response matches the fault-free bytes.
    let batcher = Batcher::with_fault(policy, plan("delay:decode_step=2,ms=400"));
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let r = batcher.submit(Request {
        id: 2,
        prompt: "Q: 8+3=? A: ".into(),
        max_tokens: 6,
        timeout_ms: Some(600_000),
        ..Default::default()
    });
    assert!(r.error.is_none(), "override must outlive the stall: {:?}", r.error);
    assert_eq!(r.text, oracle(&engine, "Q: 8+3=? A: ", 6));
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
}

/// A request whose token is already latched when a worker picks it up is
/// retired at the admission check: no slot allocated, nothing admitted.
#[test]
fn pre_cancelled_request_never_allocates_a_slot() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefix_cache: false,
            ..Default::default()
        },
        None,
    );
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let token = CancelToken::new();
    token.cancel();
    let r = batcher.submit(Request {
        id: 1,
        prompt: "Q: 2+2=? A: ".into(),
        max_tokens: 4,
        timeout_ms: None,
        cancel: Some(token),
    });
    assert_eq!(r.error.as_deref(), Some("cancelled"));
    assert_eq!(batcher.metrics.admitted.load(Ordering::Relaxed), 0);
    assert_eq!(batcher.metrics.cancelled.load(Ordering::Relaxed), 1);
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
}

/// The `{"cmd":"cancel","id":N}` wire command: acked, and the in-flight
/// streamed request's final frame arrives tagged `done` with
/// `error: "cancelled"` — the connection and server both keep working.
#[test]
fn tcp_cancel_command_retires_inflight_request() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefix_cache: false,
            ..Default::default()
        },
        // Stall before the 2nd decode step so the cancel command lands
        // while the request is still live, however fast the model runs.
        plan("delay:decode_step=2,ms=400"),
    );
    let (addr, handle) = start_server_on(engine, batcher);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.send(
        &Json::obj()
            .set("id", 5u64)
            .set("prompt", "Q: 8+9=? A: ")
            .set("max_tokens", 200u64)
            .set("stream", true),
    )
    .unwrap();
    let first = c.recv().unwrap();
    assert!(first.get("delta").is_some(), "expected a delta frame, got {first:?}");
    c.cancel(5).unwrap();
    let mut saw_ack = false;
    let fin = loop {
        let f = c.recv().unwrap();
        if f.get("cmd").and_then(Json::as_str) == Some("cancel") {
            assert_eq!(f.get("ok").and_then(Json::as_bool), Some(true));
            saw_ack = true;
            continue;
        }
        if f.get("done").and_then(Json::as_bool) == Some(true) {
            break f;
        }
        assert!(f.get("delta").is_some(), "unexpected frame: {f:?}");
    };
    assert!(saw_ack, "the cancel command must be acknowledged");
    assert_eq!(fin.get("error").and_then(Json::as_str), Some("cancelled"));

    // Same connection serves the next request normally.
    let r = c.generate("Q: 1+3=? A: ", 3).unwrap();
    assert_eq!(r.get("tokens").and_then(Json::as_usize), Some(3));
    drop(c);
    stop_server(addr, handle);
}

/// A connection that drops mid-generation cancels all of its in-flight
/// requests: the abandoned request stops consuming decode steps (the
/// `cancelled` metric ticks) and the server keeps serving.
#[test]
fn tcp_disconnect_cancels_inflight_requests() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefix_cache: false,
            ..Default::default()
        },
        plan("delay:decode_step=2,ms=400"),
    );
    let (addr, handle) = start_server_on(engine, batcher);
    {
        let mut doomed = Client::connect(&addr.to_string()).unwrap();
        doomed
            .send(
                &Json::obj()
                    .set("id", 9u64)
                    .set("prompt", "Q: 7+7=? A: ")
                    .set("max_tokens", 200u64),
            )
            .unwrap();
        // Dropped here: the server reader sees EOF and latches the token.
    }
    let mut probe = Client::connect(&addr.to_string()).unwrap();
    wait_until("the abandoned request to be cancelled", || {
        let m = probe.metrics().unwrap();
        m.get("cancelled").and_then(Json::as_usize).unwrap_or(0) >= 1
    });
    let r = probe.generate("Q: 2+5=? A: ", 3).unwrap();
    assert_eq!(r.get("tokens").and_then(Json::as_usize), Some(3));
    drop(probe);
    stop_server(addr, handle);
}

/// `--idle-timeout-ms`: a silent connection with nothing in flight is
/// closed (the client sees EOF), while a connection quietly awaiting a
/// generation longer than the idle window is left alone and gets its
/// reply.
#[test]
fn tcp_idle_timeout_closes_silent_connections_but_not_inflight() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefix_cache: false,
            idle_timeout_ms: 150,
            ..Default::default()
        },
        // The in-flight request takes ≥ 500 ms — well past the idle
        // window — so staying open proves in-flight connections are
        // exempt, not merely fast.
        plan("delay:decode_step=2,ms=500"),
    );
    let (addr, handle) = start_server_on(engine, batcher);

    let mut busy = Client::connect(&addr.to_string()).unwrap();
    busy.send(
        &Json::obj()
            .set("id", 1u64)
            .set("prompt", "Q: 9+1=? A: ")
            .set("max_tokens", 6u64),
    )
    .unwrap();
    let idle = std::net::TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(20))).unwrap();

    // The busy connection is silent for the whole stall yet never closed.
    let r = busy.recv().unwrap();
    assert!(r.get("error").is_none(), "in-flight request failed: {r:?}");
    assert_eq!(r.get("tokens").and_then(Json::as_usize), Some(6));

    // The silent connection was idle-closed: EOF, not a hang.
    let mut line = String::new();
    let n = std::io::BufReader::new(idle).read_line(&mut line).unwrap_or(0);
    assert_eq!(n, 0, "silent connection must be idle-closed");
    drop(busy);
    stop_server(addr, handle);
}

/// A panic injected at the speculative fault point — between a
/// sequence's draft and its verify forward, where the drafter has
/// already appended and rolled back KV rows — must behave exactly like
/// any other worker panic: in-flight requests on the panicking worker
/// fail with error replies, survivors stay byte-identical to the
/// fault-free oracle, the worker is respawned and serves the same bytes
/// again, and no KV slot or block outlives the crash.
#[test]
fn verify_step_panic_respawns_and_survivors_stay_byte_identical() {
    let engine = test_engine();
    let prompts: Vec<String> = (0..4).map(|i| format!("Q: {}+{}=? A: ", 4 + i, 19 - i)).collect();
    let want: Vec<String> = prompts.iter().map(|p| oracle(&engine, p, 12)).collect();

    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 2,
            prefill_chunk: 4,
            prefix_cache: false,
            spec_decode: SpecMode::SelfDraft,
            spec_k: 4,
            ..Default::default()
        },
        // The verify counter ticks once per sequence per iteration, so
        // with 12-token budgets every worker passes 6 long before its
        // load drains.
        plan("panic:verify_step=6"),
    );
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let mut joins = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let b = batcher.clone();
        let p = p.clone();
        joins.push(std::thread::spawn(move || {
            b.submit(Request {
                id: i as u64,
                prompt: p,
                max_tokens: 12,
                ..Default::default()
            })
        }));
    }
    let responses: Vec<Response> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    let failed = responses.iter().filter(|r| r.error.is_some()).count();
    assert!(
        (1..=2).contains(&failed),
        "only the panicking worker's in-flight requests may fail (got {failed})"
    );
    for r in &responses {
        match &r.error {
            Some(e) => assert!(e.contains("panicked"), "unexpected failure: {e}"),
            None => assert_eq!(
                r.text, want[r.id as usize],
                "survivor bytes must match the fault-free oracle"
            ),
        }
    }
    assert_eq!(batcher.metrics.worker_restarts.load(Ordering::Relaxed), 1);

    // The respawned worker keeps speculating, byte-identically.
    for (i, p) in prompts.iter().enumerate() {
        let r = batcher.submit(Request {
            id: 100 + i as u64,
            prompt: p.clone(),
            max_tokens: 12,
            ..Default::default()
        });
        assert!(r.error.is_none(), "post-respawn request failed: {:?}", r.error);
        assert_eq!(r.text, want[i]);
    }
    assert!(
        batcher.metrics.drafted_tokens.load(Ordering::Relaxed) > 0,
        "the run must actually have speculated"
    );
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    for (w, m) in batcher.worker_metrics().iter().enumerate() {
        assert_eq!(m.slots_in_use, 0, "worker {w} leaked a KV slot");
        assert_eq!(m.cache_blocks_in_use, 0, "worker {w} leaked KV blocks");
    }
}

/// Speculation composed with the rest of the failure machinery: radix
/// drafting + prefix cache + chunked prefill, then a mid-stream cancel
/// and a dead-on-arrival deadline on the same prompt. The block gauge
/// must return exactly to the retained-chain baseline, the resubmission
/// must reproduce the warmup bytes, and — because cached chains replay a
/// deterministic greedy decode — every radix draft must verify in full.
#[test]
fn speculative_serving_survives_cancel_and_deadline_with_gauges_at_baseline() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefill_chunk: 4,
            kv_block_size: 4,
            prefix_cache: true,
            spec_decode: SpecMode::Radix,
            spec_k: 4,
            ..Default::default()
        },
        None,
    );
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let prompt = "SYSTEM: terse.\nQ: 3+9=? A: ";

    // Warmup registers the prompt chain and its completion in the tree.
    let warm = batcher.submit(Request {
        id: 1,
        prompt: prompt.into(),
        max_tokens: 6,
        ..Default::default()
    });
    assert!(warm.error.is_none());
    wait_until("warmup gauges to publish", || {
        batcher.worker_metrics()[0].slots_in_use == 0
    });
    let baseline = batcher.worker_metrics()[0].cache_blocks_in_use;
    assert!(baseline > 0, "the retired chain must be retained for reuse");

    // Same prompt, cancelled at its first streamed token — mid-flight,
    // while radix drafts are being verified.
    let token = CancelToken::new();
    let latch = token.clone();
    let (tx, rx) = mpsc::channel();
    batcher.submit_stream_with(
        Request {
            id: 2,
            prompt: prompt.into(),
            max_tokens: 40,
            timeout_ms: None,
            cancel: Some(token),
        },
        Box::new(move |_delta| latch.cancel()),
        Box::new(move |r| {
            let _ = tx.send(r);
        }),
    );
    let r = rx.recv_timeout(Duration::from_secs(30)).expect("cancel reply");
    assert_eq!(r.error.as_deref(), Some("cancelled"));

    // Same prompt, expired at admission: never touches the pool.
    let r = batcher.submit(Request {
        id: 3,
        prompt: prompt.into(),
        max_tokens: 6,
        timeout_ms: Some(0),
        ..Default::default()
    });
    assert_eq!(r.error.as_deref(), Some("timeout"));

    // Resubmission: drafts the warmup completion and reproduces it.
    let again = batcher.submit(Request {
        id: 4,
        prompt: prompt.into(),
        max_tokens: 6,
        ..Default::default()
    });
    assert!(again.error.is_none());
    assert_eq!(again.text, warm.text, "post-failure resubmission changed bytes");

    let drafted = batcher.metrics.drafted_tokens.load(Ordering::Relaxed);
    let accepted = batcher.metrics.accepted_tokens.load(Ordering::Relaxed);
    assert!(drafted > 0, "repeat traffic must produce radix drafts");
    assert_eq!(
        accepted, drafted,
        "cached chains replay a deterministic greedy decode: full acceptance"
    );
    assert_eq!(batcher.metrics.spec_rollbacks.load(Ordering::Relaxed), 0);

    wait_until("final gauges to publish", || {
        batcher.worker_metrics()[0].slots_in_use == 0
    });
    assert_eq!(
        batcher.worker_metrics()[0].cache_blocks_in_use, baseline,
        "speculative failures must return block accounting exactly to baseline"
    );
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
}

/// A deadline that expires while a sequence is mid-speculation (forced
/// by a stall at the verify fault point) retires the request with
/// `"timeout"`; the worker then serves the next speculative request
/// normally with zero leaked KV.
#[test]
fn deadline_expires_mid_speculation_under_injected_delay() {
    let engine = test_engine();
    let batcher = Batcher::with_fault(
        BatchPolicy {
            max_batch: 2,
            engine_workers: 1,
            prefix_cache: false,
            spec_decode: SpecMode::SelfDraft,
            spec_k: 4,
            ..Default::default()
        },
        // Stall 400 ms at the 2nd verify point: the 100 ms deadline
        // expires during the stall, with the request still well under
        // its 20-token budget.
        plan("delay:verify_step=2,ms=400"),
    );
    let workers = spawn_engine_workers(&batcher, engine.fork());
    let r = batcher.submit(Request {
        id: 1,
        prompt: "Q: 6+8=? A: ".into(),
        max_tokens: 20,
        timeout_ms: Some(100),
        ..Default::default()
    });
    assert_eq!(r.error.as_deref(), Some("timeout"));
    assert_eq!(r.tokens, 0, "partial output is discarded");
    assert_eq!(batcher.metrics.timed_out.load(Ordering::Relaxed), 1);

    let ok = batcher.submit(Request {
        id: 2,
        prompt: "Q: 1+5=? A: ".into(),
        max_tokens: 3,
        ..Default::default()
    });
    assert!(ok.error.is_none());
    assert_eq!(ok.tokens, 3);

    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    let m = &batcher.worker_metrics()[0];
    assert_eq!((m.slots_in_use, m.cache_blocks_in_use), (0, 0));
}

/// Supervision over TCP with the CI fault leg's spec
/// (`panic:worker=1,decode_step=4`): pipelined load until worker 1 hits
/// its 4th decode step and is respawned — every request still gets
/// exactly one final frame (text or a worker-panic error, never
/// silence), `worker_restarts` surfaces in the metrics reply, and the
/// server keeps serving afterwards. When `SALR_FAULT` carries this exact
/// spec (the CI fault leg) the test goes through the production
/// `serve` → `Batcher::new` env path; otherwise it arms the identical
/// plan explicitly.
#[test]
fn tcp_supervision_under_panic_fault_spec() {
    const SPEC: &str = "panic:worker=1,decode_step=4";
    let engine = test_engine();
    let policy = BatchPolicy {
        max_batch: 2,
        engine_workers: 2,
        prefill_chunk: 4,
        prefix_cache: false,
        ..Default::default()
    };
    let env_armed = std::env::var("SALR_FAULT")
        .map(|s| s.trim() == SPEC)
        .unwrap_or(false);
    let (addr, handle) = if env_armed {
        let (tx, rx) = mpsc::channel();
        let e = engine.fork();
        let h = std::thread::spawn(move || {
            serve(e, "127.0.0.1:0", policy, Some(tx)).expect("serve");
        });
        (rx.recv().expect("server ready"), h)
    } else {
        start_server_on(engine.fork(), Batcher::with_fault(policy, plan(SPEC)))
    };

    let mut probe = Client::connect(&addr.to_string()).unwrap();
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let mut round = 0u64;
    loop {
        round += 1;
        for i in 0..8u64 {
            client
                .send(
                    &Json::obj()
                        .set("id", round * 100 + i)
                        .set("prompt", format!("Q: {i}+{round}=? A: "))
                        .set("max_tokens", 8u64),
                )
                .unwrap();
        }
        for _ in 0..8 {
            let r = client.recv().unwrap();
            if let Some(e) = r.get("error").and_then(Json::as_str) {
                assert!(e.contains("panicked"), "unexpected error: {e}");
            } else {
                assert_eq!(r.get("tokens").and_then(Json::as_usize), Some(8));
            }
        }
        let m = probe.metrics().unwrap();
        if m.get("worker_restarts").and_then(Json::as_usize).unwrap_or(0) >= 1 {
            break;
        }
        assert!(round < 10, "worker 1 never reached its 4th decode step");
    }
    // Post-restart, the server still serves correctly.
    let r = client.generate("Q: 2+2=? A: ", 3).unwrap();
    assert_eq!(r.get("tokens").and_then(Json::as_usize), Some(3));
    drop(client);
    drop(probe);
    stop_server(addr, handle);
}
