//! Speculative-decoding acceptance: exact verification means the token
//! stream is **bitwise identical** to non-speculative decode, no matter
//! how good or bad the drafts are. This suite pins that invariant across
//! the full configuration matrix the PR ships:
//!
//! * draft source: `radix` (prompt-lookup from the prefix-cache tree)
//!   and `self` (sparse-base-only forward),
//! * draft length k ∈ {1, 2, 4} (including k larger than the remaining
//!   token budget, so the scheduler's clamp path runs),
//! * engine workers ∈ {1, 2},
//! * prefix cache on and off,
//!
//! every cell compared byte-for-byte against the 1-worker sequential
//! whole-prefill oracle with speculation off. On top of identity the
//! suite checks the accounting: `drafted_tokens ≥ accepted_tokens`,
//! drafts actually happen where the matrix says they must, and after the
//! load drains every worker's KV/slot gauges are back at baseline (no
//! slot or block leaked to a rolled-back draft).

use salr::infer::{Backend, Engine, EngineWeights, SpecMode};
use salr::model::ParamStore;
use salr::runtime::ModelCfg;
use salr::salr::build_salr;
use salr::server::{serve, BatchPolicy, Client};
use salr::util::json::Json;
use salr::util::rng::Rng;
use std::net::SocketAddr;

fn test_cfg() -> ModelCfg {
    ModelCfg {
        name: "spec-e2e".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq_len: 96,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 4,
        batch_size: 4,
        ctx_keep: 0.5,
    }
}

/// Dense engine: adapters merged, so the self-drafting base equals the
/// full model. The degenerate-but-legal case.
fn dense_engine() -> Engine {
    let cfg = test_cfg();
    let mut rng = Rng::new(7700);
    let base = ParamStore::init_base(&cfg, &mut rng);
    Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
}

/// SALR engine: the sparse base genuinely differs from base + adapters,
/// so self-drafting can produce wrong drafts that verification must
/// correct (the case byte-identity is actually hard for).
fn salr_engine() -> Engine {
    let cfg = test_cfg();
    let mut rng = Rng::new(7701);
    let base = ParamStore::init_base(&cfg, &mut rng);
    let build = build_salr(&cfg, &base, 0.5, 3);
    let adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
    Engine::new(
        EngineWeights::salr(&cfg, &build.params, &adapters, None),
        Backend::BitmapSequential,
    )
}

fn start_server(engine: Engine, policy: BatchPolicy) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve(engine, "127.0.0.1:0", policy, Some(tx)).expect("serve");
    });
    (rx.recv().expect("server ready"), handle)
}

fn stop_server(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

/// Serve `prompts` one at a time over one connection; return the response
/// texts and the final metrics snapshot (taken after all load drained).
fn serve_sequentially(
    engine: Engine,
    policy: BatchPolicy,
    prompts: &[(String, usize)],
) -> (Vec<String>, Json) {
    let (addr, handle) = start_server(engine, policy);
    let mut texts = Vec::new();
    {
        let mut c = Client::connect(&addr.to_string()).unwrap();
        for (p, n) in prompts {
            let r = c.generate(p, *n).unwrap();
            assert!(r.get("error").is_none(), "request failed: {r:?}");
            texts.push(r.get("text").and_then(Json::as_str).unwrap().to_string());
        }
    }
    let mut probe = Client::connect(&addr.to_string()).unwrap();
    let metrics = probe.metrics().unwrap();
    drop(probe);
    stop_server(addr, handle);
    (texts, metrics)
}

fn counter(m: &Json, key: &str) -> u64 {
    m.get(key).and_then(Json::as_usize).unwrap_or_else(|| panic!("metrics missing {key}")) as u64
}

/// Every worker's end-of-run gauges: slots all free, and (when the prefix
/// cache is off) zero KV blocks still allocated. With the cache on,
/// retained chains legitimately hold blocks — but never slots.
fn assert_gauges_at_baseline(m: &Json, prefix_cache: bool, ctx: &str) {
    let workers = match m.get("workers") {
        Some(Json::Arr(w)) => w,
        other => panic!("{ctx}: metrics missing workers array, got {other:?}"),
    };
    for (i, w) in workers.iter().enumerate() {
        assert_eq!(
            w.get("slots_in_use").and_then(Json::as_usize),
            Some(0),
            "{ctx}: worker {i} leaked a KV slot"
        );
    }
    if !prefix_cache {
        assert_eq!(
            counter(m, "cache_blocks_in_use"),
            0,
            "{ctx}: cache off must end with zero blocks allocated \
             (a rolled-back draft leaked its KV blocks)"
        );
    }
}

/// Repeated prompts so radix drafting has chains to propose from (the
/// second occurrence of each prompt drafts the first one's completion),
/// with token budgets both below and above `spec_k` to run the clamp.
fn spec_prompts() -> Vec<(String, usize)> {
    let base: Vec<(String, usize)> = (0..4)
        .map(|i| (format!("Q: {}+{}=? A: ", 3 + i, 20 - i), 3 + i % 4))
        .collect();
    let mut prompts = base.clone();
    prompts.extend(base); // exact repeats: radix-draft fodder
    prompts
}

/// The full matrix on the dense engine: both drafters, k ∈ {1,2,4},
/// 1 and 2 engine workers, prefix cache on and off — all byte-identical
/// to the speculation-off 1-worker sequential whole-prefill oracle.
#[test]
fn speculative_decode_is_byte_identical_across_the_matrix() {
    let engine = dense_engine();
    let prompts = spec_prompts();

    let oracle_policy = BatchPolicy {
        max_batch: 4,
        engine_workers: 1,
        num_threads: 1,
        prefill_chunk: 0,
        prefix_cache: false,
        spec_decode: SpecMode::Off,
        ..Default::default()
    };
    let (reference, m) = serve_sequentially(engine.fork(), oracle_policy, &prompts);
    assert_eq!(counter(&m, "drafted_tokens"), 0, "spec off must never draft");
    assert_eq!(counter(&m, "accepted_tokens"), 0);
    assert_eq!(counter(&m, "spec_rollbacks"), 0);

    for &mode in &[SpecMode::Radix, SpecMode::SelfDraft] {
        for &workers in &[1usize, 2] {
            for &prefix_cache in &[false, true] {
                for &k in &[1usize, 2, 4] {
                    let ctx = format!(
                        "mode={} workers={workers} cache={prefix_cache} k={k}",
                        mode.name()
                    );
                    let policy = BatchPolicy {
                        max_batch: 4,
                        engine_workers: workers,
                        prefill_chunk: 4,
                        kv_block_size: 4,
                        prefix_cache,
                        spec_decode: mode,
                        spec_k: k,
                        ..Default::default()
                    };
                    let (texts, m) = serve_sequentially(engine.fork(), policy, &prompts);
                    assert_eq!(texts, reference, "{ctx}: speculation changed response bytes");
                    let drafted = counter(&m, "drafted_tokens");
                    let accepted = counter(&m, "accepted_tokens");
                    assert!(
                        drafted >= accepted,
                        "{ctx}: accepted {accepted} > drafted {drafted}"
                    );
                    // Where drafts are guaranteed to happen, they must:
                    // self-drafting always proposes; radix needs cached
                    // chains, which repeat prompts on one worker provide.
                    if mode == SpecMode::SelfDraft || (prefix_cache && workers == 1) {
                        assert!(drafted > 0, "{ctx}: expected speculative drafts");
                    }
                    if !prefix_cache && mode == SpecMode::Radix {
                        assert_eq!(
                            drafted, 0,
                            "{ctx}: radix drafting needs the prefix cache"
                        );
                    }
                    assert_gauges_at_baseline(&m, prefix_cache, &ctx);
                }
            }
        }
    }
}

/// The hard case for exactness: on a SALR backend the sparse base really
/// differs from the full model, so self-drafts can be wrong and the
/// verify pass must roll the KV chain back mid-stream. Bytes must still
/// match the speculation-off oracle exactly, with gauges at baseline.
#[test]
fn self_drafting_on_the_salr_backend_is_exact_under_rollbacks() {
    let engine = salr_engine();
    let prompts = spec_prompts();

    let oracle_policy = BatchPolicy {
        max_batch: 4,
        engine_workers: 1,
        num_threads: 1,
        prefill_chunk: 0,
        prefix_cache: false,
        spec_decode: SpecMode::Off,
        ..Default::default()
    };
    let (reference, _) = serve_sequentially(engine.fork(), oracle_policy, &prompts);

    for &(workers, prefix_cache) in &[(1usize, false), (1, true), (2, false), (2, true)] {
        let ctx = format!("salr self-draft workers={workers} cache={prefix_cache}");
        let policy = BatchPolicy {
            max_batch: 4,
            engine_workers: workers,
            prefill_chunk: 4,
            kv_block_size: 4,
            prefix_cache,
            spec_decode: SpecMode::SelfDraft,
            spec_k: 4,
            ..Default::default()
        };
        let (texts, m) = serve_sequentially(engine.fork(), policy, &prompts);
        assert_eq!(texts, reference, "{ctx}: speculation changed response bytes");
        let drafted = counter(&m, "drafted_tokens");
        let accepted = counter(&m, "accepted_tokens");
        assert!(drafted > 0, "{ctx}: self-drafting must draft");
        assert!(drafted >= accepted, "{ctx}: accepted > drafted");
        assert_gauges_at_baseline(&m, prefix_cache, &ctx);
    }
}

/// Radix drafting on repeated traffic is the throughput case the drafter
/// exists for: with one worker and the prefix cache on, the second serving
/// of each prompt drafts the first serving's completion, and greedy
/// determinism makes every one of those drafts accepted in full.
#[test]
fn radix_drafting_accepts_repeated_completions_in_full() {
    let engine = dense_engine();
    let prompts = spec_prompts();
    let policy = BatchPolicy {
        max_batch: 4,
        engine_workers: 1,
        prefill_chunk: 4,
        kv_block_size: 4,
        prefix_cache: true,
        spec_decode: SpecMode::Radix,
        spec_k: 4,
        ..Default::default()
    };
    let (_, m) = serve_sequentially(engine.fork(), policy, &prompts);
    let drafted = counter(&m, "drafted_tokens");
    let accepted = counter(&m, "accepted_tokens");
    assert!(drafted > 0, "repeat traffic must produce radix drafts");
    assert_eq!(
        accepted, drafted,
        "cached continuations of a deterministic greedy decode must be \
         accepted in full (a rejection means verify and decode disagree)"
    );
    assert_eq!(counter(&m, "spec_rollbacks"), 0);
}
