//! End-to-end tracing integration: span trees stitched across the
//! router and engine tiers, trace-id survival through failover, Chrome
//! trace export, and the observability invariant that matters most —
//! tracing never changes a single output byte.
//!
//! Tracing enablement is process-global (per-thread rings in one
//! registry, one `ENABLED` flag), so every test here serializes on
//! [`TRACE_LOCK`]. Assertions are presence-based ("the tree contains a
//! `failover` span"), never exact counts: router request ids — and
//! therefore router-minted trace ids — restart at 1 per [`Router`], so
//! a trace id can collide across tests in this binary and pick up
//! spans recorded by an earlier test sharing the registry. Presence
//! assertions are immune to that; count assertions would be flaky.
//!
//! CI runs this file in the ordinary matrix (each test enables tracing
//! itself) and again in the `SALR_TRACE=1` leg, where `serve_on` /
//! `serve_router_on` arm tracing through the production
//! `init_from_env` path before any test-side `set_enabled` call.

use salr::data::{detokenize, tokenize};
use salr::infer::{Backend, Engine, EngineWeights};
use salr::model::ParamStore;
use salr::runtime::ModelCfg;
use salr::server::{serve_on, serve_router_on, BatchPolicy, Batcher, Client, Router, RouterPolicy};
use salr::util::fault::FaultPlan;
use salr::util::json::Json;
use salr::util::rng::Rng;
use salr::util::trace;
use std::net::SocketAddr;
use std::sync::{mpsc, Arc, Mutex};

/// Serializes the tests in this binary: tracing state and the span
/// registry are process-global.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn test_engine() -> Engine {
    let cfg = ModelCfg {
        name: "trace-e2e".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        max_seq_len: 96,
        rank: 4,
        lora_alpha: 8.0,
        residual_rank: 4,
        batch_size: 2,
        ctx_keep: 0.5,
    };
    let mut rng = Rng::new(700);
    let base = ParamStore::init_base(&cfg, &mut rng);
    Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
}

fn oracle(engine: &Engine, prompt: &str, max_tokens: usize) -> String {
    let out = engine.generate_batch(&[tokenize(prompt)], max_tokens);
    detokenize(&out[0])
}

/// Chunked prefill on purpose: a traced request then shows several
/// `prefill_chunk` spans with kernel spans nested inside them.
fn backend_policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 4,
        engine_workers: 1,
        prefill_chunk: 4,
        prefix_cache: false,
        ..Default::default()
    }
}

fn start_backend(engine: Engine) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let batcher = Batcher::with_fault(backend_policy(), None);
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve_on(engine, "127.0.0.1:0", batcher, Some(tx)).expect("backend serve");
    });
    (rx.recv().expect("backend ready"), handle)
}

fn router_policy() -> RouterPolicy {
    RouterPolicy {
        heartbeat_ms: 20,
        spill_depth: 1_000,
        ..RouterPolicy::default()
    }
}

fn start_router(router: &Arc<Router>) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let r = router.clone();
    let handle = std::thread::spawn(move || {
        serve_router_on(r, "127.0.0.1:0", Some(tx)).expect("router serve");
    });
    (rx.recv().expect("router ready"), handle)
}

fn wait_all_healthy(router_addr: SocketAddr, n: usize) {
    let mut probe = Client::connect(&router_addr.to_string()).unwrap();
    let t0 = std::time::Instant::now();
    loop {
        let m = probe.metrics().unwrap();
        let healthy = (0..n).all(|i| {
            m.get("backends").and_then(Json::as_arr).expect("backends")[i]
                .get("backend_state")
                .and_then(Json::as_str)
                == Some("healthy")
        });
        if healthy {
            return;
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "timed out waiting for healthy backends"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

fn prompt_owned_by(router: &Router, owner: usize, tag: &str) -> String {
    for i in 0..10_000 {
        let p = format!("Q: {tag}{i}+2=? A: ");
        if router.owner_of_prompt(&p) == owner {
            return p;
        }
    }
    panic!("no prompt found with owner {owner}");
}

fn stop(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

/// Every span kind in a trace-reply tree, depth-first (the tree nodes
/// nest kernel spans under their enclosing request-tier spans).
fn collect(node: &Json, kinds: &mut Vec<(String, String)>) {
    let kind = node.get("kind").and_then(Json::as_str).unwrap_or("?").to_string();
    let proc_name = node.get("proc").and_then(Json::as_str).unwrap_or("?").to_string();
    kinds.push((kind, proc_name));
    if let Some(children) = node.get("children").and_then(Json::as_arr) {
        for c in children {
            collect(c, kinds);
        }
    }
}

fn tree_kinds(reply: &Json) -> Vec<(String, String)> {
    let mut kinds = Vec::new();
    for root in reply.get("tree").and_then(Json::as_arr).expect("trace tree") {
        collect(root, &mut kinds);
    }
    kinds
}

fn has_kind(kinds: &[(String, String)], kind: &str) -> bool {
    kinds.iter().any(|(k, _)| k == kind)
}

/// The stitching acceptance bar: one request submitted through the
/// router yields — via `{"cmd":"trace","id":N}` on the router — a
/// single span tree whose id came back on the final reply frame,
/// containing the router's `admit` and the backend's
/// `prefill_chunk`/`decode_step`/`retire` spans, with kernel-tier
/// `gemm_call`/`pack_b` spans nested inside the traced prefill.
#[test]
fn router_request_yields_stitched_span_tree() {
    let _g = lock();
    trace::set_enabled(true);
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    let (a1, h1) = start_backend(engine.fork());
    let router = Router::with_fault(
        &[a0.to_string(), a1.to_string()],
        router_policy(),
        None,
    );
    let (ra, rh) = start_router(&router);
    wait_all_healthy(ra, 2);

    let prompt = prompt_owned_by(&router, 0, "stitch");
    let mut c = Client::connect(&ra.to_string()).unwrap();
    let r = c.generate(&prompt, 8).unwrap();
    assert!(r.get("error").is_none(), "traced request failed: {r:?}");
    assert_eq!(
        r.get("text").and_then(Json::as_str),
        Some(oracle(&engine, &prompt, 8).as_str()),
        "tracing must not change the bytes"
    );
    let tid = r
        .get("trace")
        .and_then(Json::as_usize)
        .expect("final frame carries the trace id") as u64;
    assert!(tid > 0);

    let reply = c.trace(tid).unwrap();
    assert!(reply.get("error").is_none(), "trace lookup failed: {reply:?}");
    assert_eq!(reply.get("id").and_then(Json::as_usize), Some(tid as usize));
    let kinds = tree_kinds(&reply);
    for want in ["admit", "prefill_chunk", "decode_step", "retire"] {
        assert!(has_kind(&kinds, want), "span tree missing {want}: {kinds:?}");
    }
    assert!(
        has_kind(&kinds, "gemm_call") || has_kind(&kinds, "pack_b"),
        "kernel-tier spans missing from the tree: {kinds:?}"
    );
    // Stitched means both tiers contributed: the router's own spans and
    // the backend's, merged into one reply. (In these in-process tests
    // both tiers share one span registry, so the local tree already
    // carries "serve" spans — the assertion still pins that the merged
    // reply names both processes.)
    let procs: Vec<&str> = kinds.iter().map(|(_, p)| p.as_str()).collect();
    assert!(procs.contains(&"router"), "no router-proc spans: {kinds:?}");
    assert!(procs.contains(&"serve"), "no serve-proc spans: {kinds:?}");
    // Kernel spans nest under the traced prefill, not float at top level.
    let nested_kernel = reply
        .get("tree")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .any(|root| {
            fn prefill_with_kernel_child(n: &Json) -> bool {
                let is_prefill =
                    n.get("kind").and_then(Json::as_str) == Some("prefill_chunk");
                let kids = n.get("children").and_then(Json::as_arr).unwrap_or(&[]);
                if is_prefill
                    && kids.iter().any(|c| {
                        matches!(
                            c.get("kind").and_then(Json::as_str),
                            Some("gemm_call") | Some("pack_b")
                        )
                    })
                {
                    return true;
                }
                kids.iter().any(prefill_with_kernel_child)
            }
            prefill_with_kernel_child(root)
        });
    assert!(nested_kernel, "no kernel span nested under a prefill_chunk");

    drop(c);
    stop(ra, rh);
    stop(a0, h0);
    stop(a1, h1);
}

/// Trace ids survive failover: a request whose first backend dies
/// before its first token is retried on another backend under the SAME
/// trace id, and the span tree shows the `failover` event between the
/// two dispatch attempts — one request, one id, one tree.
#[test]
fn trace_id_survives_failover_with_failover_span() {
    let _g = lock();
    trace::set_enabled(true);
    let engine = test_engine();
    let (a0, h0) = start_backend(engine.fork());
    let (a1, h1) = start_backend(engine.fork());
    let fault = FaultPlan::parse("conn_drop:backend=0,fwd=1").expect("fault spec");
    let router = Router::with_fault(
        &[a0.to_string(), a1.to_string()],
        router_policy(),
        Some(fault),
    );
    let (ra, rh) = start_router(&router);
    wait_all_healthy(ra, 2);

    let prompt = prompt_owned_by(&router, 0, "failover");
    let mut c = Client::connect(&ra.to_string()).unwrap();
    let r = c.generate(&prompt, 8).unwrap();
    assert!(r.get("error").is_none(), "failover must be transparent: {r:?}");
    assert_eq!(
        r.get("text").and_then(Json::as_str),
        Some(oracle(&engine, &prompt, 8).as_str())
    );
    let tid = r
        .get("trace")
        .and_then(Json::as_usize)
        .expect("failed-over final still carries its trace id") as u64;

    let reply = c.trace(tid).unwrap();
    let kinds = tree_kinds(&reply);
    assert!(
        has_kind(&kinds, "failover"),
        "span tree must record the failover between attempts: {kinds:?}"
    );
    // The second attempt's serve-side spans landed under the same id.
    for want in ["admit", "retire"] {
        assert!(has_kind(&kinds, want), "span tree missing {want}: {kinds:?}");
    }

    assert_eq!(
        c.metrics().unwrap().get("failovers").and_then(Json::as_usize),
        Some(1)
    );

    drop(c);
    stop(ra, rh);
    stop(a0, h0);
    stop(a1, h1);
}

/// The determinism bar: the same prompts produce byte-identical token
/// streams with tracing off and on — against a direct `serve` backend,
/// whose final frames carry a serve-minted trace id when tracing is on
/// and no `"trace"` field at all when it is off.
#[test]
fn tokens_are_byte_identical_with_tracing_on_and_off() {
    let _g = lock();
    let prompts = ["Q: 3+4=? A: ", "Q: 12+9=? A: ", "Q: 7+1=? A: "];
    let engine = test_engine();
    let mut runs: Vec<Vec<String>> = Vec::new();
    // The "off" half is only genuinely off outside the SALR_TRACE=1 CI
    // leg (serve_on's init_from_env re-arms from the env and never
    // disables); either way both halves must produce the same bytes.
    let env_on = std::env::var("SALR_TRACE")
        .map(|v| salr::util::truthy(&v))
        .unwrap_or(false);
    for on in [false, true] {
        trace::set_enabled(on);
        let (addr, handle) = start_backend(engine.fork());
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let mut texts = Vec::new();
        for p in &prompts {
            let r = c.generate(p, 10).unwrap();
            assert!(r.get("error").is_none(), "request failed: {r:?}");
            let traced = r.get("trace").and_then(Json::as_usize);
            if on || env_on {
                let tid = traced.expect("traced final carries an id");
                assert!(tid > 0);
            } else {
                assert_eq!(traced, None, "untraced final must not carry an id");
            }
            texts.push(r.get("text").and_then(Json::as_str).unwrap().to_string());
        }
        drop(c);
        stop(addr, handle);
        runs.push(texts);
    }
    assert_eq!(runs[0], runs[1], "tracing changed the output bytes");
    for (p, text) in prompts.iter().zip(&runs[1]) {
        assert_eq!(text, &oracle(&engine, p, 10), "traced run diverged from oracle");
    }
}

/// `--trace-out` / `write_chrome_trace`: after traced requests, the
/// dump is valid Chrome trace_event JSON — a `traceEvents` array of
/// complete (`ph:"X"`) events with ts/dur/pid/tid and the request-tier
/// span names, plus thread-name metadata events.
#[test]
fn chrome_trace_dump_is_valid_and_covers_the_request() {
    let _g = lock();
    trace::set_enabled(true);
    let engine = test_engine();
    let (addr, handle) = start_backend(engine.fork());
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c.generate("Q: 5+6=? A: ", 8).unwrap();
    assert!(r.get("error").is_none(), "request failed: {r:?}");
    drop(c);
    stop(addr, handle);

    let path = std::env::temp_dir().join(format!(
        "salr_trace_test_{}.json",
        std::process::id()
    ));
    let path = path.to_str().unwrap().to_string();
    trace::write_chrome_trace(&path, "serve").expect("chrome trace written");
    let text = std::fs::read_to_string(&path).unwrap();
    let doc = Json::parse(&text).expect("dump must be valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    let mut names = std::collections::HashSet::new();
    let mut metadata = 0;
    for e in events {
        match e.get("ph").and_then(Json::as_str) {
            Some("X") => {
                assert!(e.get("ts").is_some() && e.get("dur").is_some());
                assert!(e.get("pid").is_some() && e.get("tid").is_some());
                names.insert(e.get("name").and_then(Json::as_str).unwrap().to_string());
            }
            Some("M") => metadata += 1,
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert!(metadata > 0, "thread_name metadata events missing");
    for want in ["admit", "prefill_chunk", "decode_step", "retire"] {
        assert!(names.contains(want), "dump missing {want} events: {names:?}");
    }
    assert!(
        names.contains("gemm_call") || names.contains("pack_b"),
        "dump missing kernel-tier events: {names:?}"
    );
    std::fs::remove_file(&path).ok();
}

/// The serve tier's `{"cmd":"metrics"}` reply now carries the
/// lock-free latency histograms and per-stage span totals.
#[test]
fn metrics_reply_carries_histograms_and_stage_totals() {
    let _g = lock();
    trace::set_enabled(true);
    let engine = test_engine();
    let (addr, handle) = start_backend(engine.fork());
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.generate("Q: 2+2=? A: ", 6).unwrap();
    let m = c.metrics().unwrap();
    let hist = m.get("hist").expect("hist object");
    for h in ["queue_wait", "ttft", "per_token", "e2e"] {
        let hj = hist.get(h).unwrap_or_else(|| panic!("hist.{h} missing"));
        assert!(
            hj.get("count").and_then(Json::as_usize).unwrap() > 0,
            "hist.{h} recorded nothing"
        );
        assert!(hj.get("p50_us").is_some() && hj.get("p99_us").is_some());
    }
    let stages = m.get("stages").expect("stages object");
    for k in ["prefill_chunk", "decode_step", "retire"] {
        assert!(
            stages.get(k).and_then(|s| s.get("count")).and_then(Json::as_usize).unwrap() > 0,
            "stages.{k} recorded nothing"
        );
    }
    assert!(m.get("trace_dropped").is_some());
    drop(c);
    stop(addr, handle);
}
