//! Model-based randomized testing of the KV-cache core: drive
//! [`RadixTree`] + [`BlockPool`] through seeded random op sequences
//! (insert / lookup / propose / pin / unpin / evict) and after **every**
//! op compare the real structures against a naive reference model — a
//! flat map from full-block token paths to block ids plus a pin ledger.
//!
//! The invariants the model makes checkable:
//!
//! * **Refcount exactness** — every cached block's pool refcount is
//!   exactly `1 (tree) + pins`, blocks the tree declined to retain
//!   (duplicate inserts) free immediately, and `blocks_in_use` equals
//!   the model's cardinality. No leaks, no double-frees, ever.
//! * **Ancestor closure** — every proper block-prefix of a cached path
//!   is itself cached: chains never dangle mid-path.
//! * **Eviction safety** — `evict_one` removes exactly one *leaf* whose
//!   block no sequence pins; it never truncates a chain something still
//!   references, and it reports `false` only when the model agrees
//!   nothing is evictable.
//! * **Draft consistency** — every `propose` continuation spells a path
//!   that is actually cached (speculation can only draft real chains).
//!
//! Token labels are 2-token pairs `[2v, 2v+1]`, so two distinct labels
//! never share a token: lookups either match a block fully or not at
//! all, which keeps the reference model exact without modeling
//! mid-block partial matches (those are unit-tested in `cache::radix`).

use salr::infer::cache::{BlockPool, RadixTree};
use salr::util::rng::Rng;
use std::collections::HashMap;

const BS: usize = 2; // tokens per block
const ALPHABET: usize = 4; // distinct labels
const MAX_DEPTH: usize = 3;

fn label(v: usize) -> [i32; BS] {
    [2 * v as i32, 2 * v as i32 + 1]
}

fn random_path(rng: &mut Rng) -> Vec<i32> {
    let depth = rng.range(1, MAX_DEPTH + 1);
    let mut tokens = Vec::with_capacity(depth * BS);
    for _ in 0..depth {
        tokens.extend_from_slice(&label(rng.below(ALPHABET)));
    }
    tokens
}

/// The naive reference: cached full-block paths → block id, plus how
/// many extra (sequence) refs we hold per block.
struct Model {
    paths: HashMap<Vec<i32>, usize>,
    pins: HashMap<usize, u32>,
}

impl Model {
    fn pins_on(&self, block: usize) -> u32 {
        self.pins.get(&block).copied().unwrap_or(0)
    }

    /// A path is a leaf when no cached path extends it.
    fn is_leaf(&self, path: &[i32]) -> bool {
        !self
            .paths
            .keys()
            .any(|p| p.len() > path.len() && p[..path.len()] == *path)
    }

    /// Does the model predict an evictable node (leaf + unpinned)?
    fn has_evictable(&self) -> bool {
        self.paths
            .iter()
            .any(|(path, &b)| self.is_leaf(path) && self.pins_on(b) == 0)
    }

    /// Every invariant that must hold between ops.
    fn check(&self, tree: &mut RadixTree, pool: &BlockPool) {
        assert_eq!(
            pool.blocks_in_use(),
            self.paths.len(),
            "blocks in use must equal cached paths (leak or double-free)"
        );
        assert_eq!(tree.len(), self.paths.len(), "node count diverged");
        for (path, &block) in &self.paths {
            // Refcount exactness: one tree ref plus our pins, no more.
            assert_eq!(
                pool.refcount(block),
                1 + self.pins_on(block),
                "refcount of block {block} (path {path:?}) is not tree+pins"
            );
            // Ancestor closure: every proper block-prefix is cached too.
            let mut n = BS;
            while n < path.len() {
                assert!(
                    self.paths.contains_key(&path[..n]),
                    "path {path:?} cached without its ancestor {:?}",
                    &path[..n]
                );
                n += BS;
            }
            // The real tree serves the whole chain, in order.
            let (full, partial) = tree.lookup(path);
            let want: Vec<usize> = (1..=path.len() / BS)
                .map(|i| self.paths[&path[..i * BS]])
                .collect();
            let got: Vec<usize> = full.iter().map(|m| m.block).collect();
            assert_eq!(got, want, "lookup of {path:?} lost part of its chain");
            assert!(partial.is_none(), "whole-label paths never match partially");
        }
    }
}

#[test]
fn radix_tree_and_block_pool_match_a_naive_reference_model() {
    let mut seed_rng = Rng::new(0xCAC4E_0D31);
    for round in 0..12u64 {
        let mut rng = seed_rng.fork(round);
        // Sized past the worst case (4 + 16 + 64 distinct paths) plus
        // transient insert allocations, so churn never exhausts the pool.
        let mut pool = BlockPool::new(96, 1, BS, 1);
        let mut tree = RadixTree::new(BS);
        let mut model = Model {
            paths: HashMap::new(),
            pins: HashMap::new(),
        };
        for op in 0..120 {
            match rng.below(12) {
                0..=4 => {
                    // Insert a random path; the tree retains blocks only
                    // for prefixes it does not already cache.
                    let tokens = random_path(&mut rng);
                    let blocks: Vec<usize> = (0..tokens.len() / BS)
                        .map(|_| pool.alloc().expect("pool sized for the churn"))
                        .collect();
                    tree.insert(&tokens, &blocks, &mut pool);
                    for (i, &b) in blocks.iter().enumerate() {
                        let prefix = tokens[..(i + 1) * BS].to_vec();
                        if !model.paths.contains_key(&prefix) {
                            model.paths.insert(prefix, b);
                        }
                        // Drop the sequence's own ref: duplicates free
                        // here; retained blocks drop to the tree ref.
                        pool.release(b);
                    }
                }
                5..=6 => {
                    // Recency churn (the model is order-blind; this only
                    // stresses that recency bumps never corrupt state).
                    let _ = tree.lookup(&random_path(&mut rng));
                }
                7 => {
                    // Draft consistency: whatever propose returns must
                    // spell a cached chain continuing the history.
                    let hist = random_path(&mut rng);
                    let k = rng.range(1, 7);
                    let out = tree.propose(&hist, k);
                    assert!(out.len() <= k, "draft longer than requested");
                    if !out.is_empty() {
                        let mut combined = hist.clone();
                        combined.extend_from_slice(&out);
                        let mut n = BS;
                        while n <= combined.len() {
                            assert!(
                                model.paths.contains_key(&combined[..n]),
                                "proposed continuation {out:?} of {hist:?} is \
                                 not a cached chain at prefix {:?}",
                                &combined[..n]
                            );
                            n += BS;
                        }
                    }
                }
                8 => {
                    // Pin a random cached block, as an attached sequence.
                    if !model.paths.is_empty() {
                        let blocks: Vec<usize> = model.paths.values().copied().collect();
                        let b = blocks[rng.below(blocks.len())];
                        pool.retain(b);
                        *model.pins.entry(b).or_insert(0) += 1;
                    }
                }
                9 => {
                    // Unpin one.
                    let pinned: Vec<usize> = model
                        .pins
                        .iter()
                        .filter(|(_, &c)| c > 0)
                        .map(|(&b, _)| b)
                        .collect();
                    if !pinned.is_empty() {
                        let b = pinned[rng.below(pinned.len())];
                        pool.release(b);
                        *model.pins.get_mut(&b).unwrap() -= 1;
                    }
                }
                _ => {
                    // Evict, and hold the tree to the model's verdict.
                    let predicted = model.has_evictable();
                    let got = tree.evict_one(&mut pool);
                    assert_eq!(
                        got, predicted,
                        "op {op}: evict_one disagreed with the model about \
                         whether an unpinned leaf exists"
                    );
                    if got {
                        // Exactly one path lost its tree ref; it must have
                        // been an unpinned leaf. (Blocks are unique per
                        // node, so the refcount drop identifies it.)
                        let gone: Vec<Vec<i32>> = model
                            .paths
                            .iter()
                            .filter(|(_, &b)| pool.refcount(b) == model.pins_on(b))
                            .map(|(p, _)| p.clone())
                            .collect();
                        assert_eq!(
                            gone.len(),
                            1,
                            "eviction must remove exactly one node, removed {gone:?}"
                        );
                        let victim = &gone[0];
                        assert!(
                            model.is_leaf(victim),
                            "evicted {victim:?} still has cached descendants \
                             (eviction truncated a referenced chain)"
                        );
                        let b = model.paths[victim];
                        assert_eq!(
                            model.pins_on(b),
                            0,
                            "evicted {victim:?} while a sequence pinned it"
                        );
                        model.paths.remove(&gone[0]);
                    }
                }
            }
            model.check(&mut tree, &pool);
        }
        // Drain: unpin everything, then eviction must empty the cache.
        for (&b, &c) in &model.pins {
            for _ in 0..c {
                pool.release(b);
            }
        }
        model.pins.clear();
        while tree.evict_one(&mut pool) {}
        assert!(tree.is_empty(), "round {round}: drain left nodes behind");
        assert_eq!(pool.blocks_in_use(), 0, "round {round}: drain leaked blocks");
    }
}
