"""L2 model correctness: shapes, variant semantics, gradient flow, and the
optimizer update rules that get baked into the AOT train-step artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import ModelConfig, get_config

CFG = ModelConfig(
    name="test", d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq_len=16,
    rank=4, residual_rank=8, batch_size=2, vocab_size=64,
)


@pytest.fixture(scope="module")
def setup():
    params = M.init_base_params(CFG, jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (CFG.batch_size, CFG.max_seq_len), 0, CFG.vocab_size
    )
    mask = jnp.ones_like(tokens, dtype=jnp.float32)
    return params, tokens, mask


def test_param_shapes_and_count(setup):
    params, _, _ = setup
    assert params["embed"].shape == (CFG.vocab_size, CFG.d_model)
    assert params["lm_head"].shape == (CFG.d_model, CFG.vocab_size)
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == CFG.param_count()


def test_forward_shapes_all_variants(setup):
    params, tokens, _ = setup
    for variant in M.VARIANTS:
        frozen = dict(params)
        if variant == "losa":
            frozen.update(M.init_masks(CFG))
        tr = (
            {}
            if variant == "dense"
            else M.init_adapters(CFG, jax.random.PRNGKey(2), variant == "salr")
        )
        logits = M.forward(CFG, variant, frozen, tr, tokens)
        assert logits.shape == (CFG.batch_size, CFG.max_seq_len, CFG.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_fresh_adapters_are_identity(setup):
    """B = 0 at init → lora/salr/losa(ones-mask) forward == dense forward."""
    params, tokens, _ = setup
    dense = M.forward(CFG, "dense", params, {}, tokens)
    tr = M.init_adapters(CFG, jax.random.PRNGKey(2), with_residual=True)
    lora = M.forward(CFG, "lora", params, tr, tokens)
    np.testing.assert_allclose(np.asarray(lora), np.asarray(dense), atol=1e-5)
    salr = M.forward(CFG, "salr", params, tr, tokens)
    np.testing.assert_allclose(np.asarray(salr), np.asarray(dense), atol=1e-5)
    frozen = dict(params)
    frozen.update(M.init_masks(CFG))  # all-ones mask
    losa = M.forward(CFG, "losa", frozen, tr, tokens)
    np.testing.assert_allclose(np.asarray(losa), np.asarray(dense), atol=1e-5)


def test_losa_mask_actually_masks(setup):
    params, tokens, _ = setup
    tr = M.init_adapters(CFG, jax.random.PRNGKey(2), False)
    frozen = dict(params)
    masks = {k: jnp.zeros_like(v) for k, v in M.init_masks(CFG).items()}
    frozen.update(masks)
    # All-zero masks kill every adapted linear: logits become position-only.
    losa = M.forward(CFG, "losa", frozen, tr, tokens)
    assert bool(jnp.all(jnp.isfinite(losa)))
    dense = M.forward(CFG, "dense", params, {}, tokens)
    assert float(jnp.max(jnp.abs(losa - dense))) > 1e-3


def test_salr_concat_equals_separate_adapters(setup):
    """Adapter concatenation (paper) == sum of separate adapter products."""
    params, tokens, _ = setup
    tr = M.init_adapters(CFG, jax.random.PRNGKey(3), with_residual=True)
    # Give nonzero B and residual factors.
    tr = {
        k: (jax.random.normal(jax.random.PRNGKey(i), v.shape) * 0.05).astype(
            jnp.float32
        )
        for i, (k, v) in enumerate(sorted(tr.items()))
    }
    x = jax.random.normal(jax.random.PRNGKey(9), (4, CFG.d_model))
    name = "layer0.wq"
    w = params[name]
    got = M._adapted_linear(CFG, "salr", x, w, tr, {}, name)
    s = CFG.lora_scaling
    want = (
        x @ w
        + (x @ tr[f"{name}.lora_a"]) @ tr[f"{name}.lora_b"] * s
        + (x @ tr[f"{name}.res_a"]) @ tr[f"{name}.res_b"]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_pretrain_loss_decreases(setup):
    params, tokens, mask = setup
    step = jax.jit(M.pretrain_step(CFG))
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    p = params
    losses = []
    for t in range(1, 9):
        p, m, v, loss = step(p, m, v, jnp.float32(t), tokens, mask, jnp.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


@pytest.mark.parametrize("variant", ["lora", "salr", "losa", "sparselora"])
def test_finetune_updates_only_trainable(variant, setup):
    params, tokens, mask = setup
    frozen = dict(params)
    if variant == "losa":
        frozen.update(M.init_masks(CFG))
    tr = M.init_adapters(CFG, jax.random.PRNGKey(2), variant == "salr")
    step = jax.jit(M.finetune_step(CFG, variant))
    m = {k: jnp.zeros_like(x) for k, x in tr.items()}
    v = {k: jnp.zeros_like(x) for k, x in tr.items()}
    tr2, m2, v2, loss = step(
        frozen, tr, m, v, jnp.float32(1), tokens, mask, jnp.float32(1e-3), jnp.float32(1e-2)
    )
    assert np.isfinite(float(loss))
    # LoRA A gets a gradient only after B != 0; B always gets one.
    changed = sum(
        int(not np.allclose(np.asarray(tr[k]), np.asarray(tr2[k]))) for k in tr
    )
    assert changed > 0


def test_residual_frozen_when_eta_zero(setup):
    """eta = 0 freezes the residual adapters (Table-5 ablation switch)."""
    params, tokens, mask = setup
    tr = M.init_adapters(CFG, jax.random.PRNGKey(2), with_residual=True)
    # Make residual nonzero so it would receive gradient.
    tr["layer0.wq.res_a"] = jnp.ones_like(tr["layer0.wq.res_a"]) * 0.1
    tr["layer0.wq.res_b"] = jnp.ones_like(tr["layer0.wq.res_b"]) * 0.1
    step = jax.jit(M.finetune_step(CFG, "salr"))
    m = {k: jnp.zeros_like(x) for k, x in tr.items()}
    v = {k: jnp.zeros_like(x) for k, x in tr.items()}
    tr2, _, _, _ = step(
        dict(params), tr, m, v, jnp.float32(1), tokens, mask,
        jnp.float32(1e-3), jnp.float32(0.0),
    )
    for k in tr:
        if k.endswith(M.RES_SUFFIXES):
            np.testing.assert_array_equal(np.asarray(tr2[k]), np.asarray(tr[k]))
    # With eta > 0 the (nonzero) residual moves.
    tr3, _, _, _ = step(
        dict(params), tr, m, v, jnp.float32(1), tokens, mask,
        jnp.float32(1e-3), jnp.float32(1e-2),
    )
    assert not np.allclose(
        np.asarray(tr3["layer0.wq.res_a"]), np.asarray(tr["layer0.wq.res_a"])
    )


def test_finetune_loss_decreases_lora(setup):
    params, tokens, mask = setup
    tr = M.init_adapters(CFG, jax.random.PRNGKey(2), False)
    step = jax.jit(M.finetune_step(CFG, "lora"))
    m = {k: jnp.zeros_like(x) for k, x in tr.items()}
    v = {k: jnp.zeros_like(x) for k, x in tr.items()}
    losses = []
    for t in range(1, 13):
        tr, m, v, loss = step(
            dict(params), tr, m, v, jnp.float32(t), tokens, mask,
            jnp.float32(5e-3), jnp.float32(0.0),
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_loss_mask_excludes_positions(setup):
    params, tokens, _ = setup
    full = jnp.ones((CFG.batch_size, CFG.max_seq_len), jnp.float32)
    half = full.at[:, : CFG.max_seq_len // 2].set(0.0)
    l_full = float(M.loss_fn(CFG, "dense", params, {}, tokens, full))
    l_half = float(M.loss_fn(CFG, "dense", params, {}, tokens, half))
    assert l_full != l_half
    zero = jnp.zeros_like(full)
    l_zero = float(M.loss_fn(CFG, "dense", params, {}, tokens, zero))
    assert l_zero == 0.0


def test_causality(setup):
    """Changing a future token must not change past logits."""
    params, tokens, _ = setup
    logits1 = M.forward(CFG, "dense", params, {}, tokens)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab_size)
    logits2 = M.forward(CFG, "dense", params, {}, tokens2)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5
    )


def test_key_ordering_is_sorted():
    """The manifest/rust contract: dict flattening is sorted-key order."""
    fkeys = M.frozen_keys(CFG, "lora")
    tkeys = M.trainable_keys(CFG, "salr")
    assert fkeys == sorted(fkeys)
    assert tkeys == sorted(tkeys)
    assert any(k.endswith(".res_a") for k in tkeys)
    assert not any(
        k.endswith(".res_a") for k in M.trainable_keys(CFG, "lora")
    )
    losa_fkeys = M.frozen_keys(CFG, "losa")
    assert any(k.endswith(".mask") for k in losa_fkeys)


def test_configs_exist():
    for name in ("tiny", "small"):
        cfg = get_config(name)
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.param_count() > 0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
