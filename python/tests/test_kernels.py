"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes/sparsities/seeds; assert_allclose is the gate.
This is the CORE correctness signal for the kernels that get lowered into
the AOT artifacts.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    bitmap_decode,
    bitmap_matmul,
    fused_adapter,
    nf4_dequant,
    nf4_matmul,
    ref,
    salr_linear,
    sequential_adapters,
)

SETTINGS = dict(max_examples=12, deadline=None)


def encode_bitmap(w: np.ndarray):
    """numpy bitmap encoder matching rust's sparse::BitmapMatrix layout
    (32-bit words, bit t of word b = column 32b+t, row-major values)."""
    k, n = w.shape
    wpr = (n + 31) // 32
    words = np.zeros((k, wpr), dtype=np.uint32)
    vals, offs = [], []
    for i in range(k):
        offs.append(len(vals))
        row = w[i]
        nz = np.nonzero(row)[0]
        for j in nz:
            words[i, j // 32] |= np.uint32(1) << np.uint32(j % 32)
            vals.append(row[j])
    vals.append(0.0)  # guard so values is never empty
    return words, np.array(vals, dtype=np.float32), np.array(offs, dtype=np.int32)


def sparse_matrix(rng, k, n, sparsity):
    w = rng.normal(size=(k, n)).astype(np.float32)
    flat = np.abs(w).flatten()
    thresh = np.quantile(flat, sparsity) if sparsity > 0 else -1.0
    w[np.abs(w) <= thresh] = 0.0
    return w


@given(
    k=st.integers(4, 80),
    n=st.integers(4, 80),
    sparsity=st.sampled_from([0.0, 0.3, 0.5, 0.9]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_bitmap_decode_matches_ref_and_dense(k, n, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = sparse_matrix(rng, k, n, sparsity)
    words, vals, offs = encode_bitmap(w)
    dec_ref = np.asarray(ref.bitmap_decode_ref(words, vals, offs, n))
    np.testing.assert_allclose(dec_ref, w, atol=0)
    dec_kernel = np.asarray(bitmap_decode(words, vals, offs, n, block_k=16))
    np.testing.assert_allclose(dec_kernel, w, atol=0)


@given(
    m=st.integers(1, 24),
    k=st.integers(4, 64),
    n=st.integers(4, 64),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_bitmap_matmul_matches_dense(m, k, n, seed):
    rng = np.random.default_rng(seed)
    w = sparse_matrix(rng, k, n, 0.5)
    x = rng.normal(size=(m, k)).astype(np.float32)
    words, vals, offs = encode_bitmap(w)
    got = np.asarray(bitmap_matmul(x, words, vals, offs, n, block_m=8, block_k=16))
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(1, 16),
    k=st.integers(4, 48),
    n=st.integers(4, 48),
    ranks=st.lists(st.integers(1, 8), min_size=1, max_size=4),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_fused_adapter_equals_sequential_sum(m, k, n, ranks, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    adapters = [
        (
            rng.normal(size=(k, r)).astype(np.float32),
            rng.normal(size=(r, n)).astype(np.float32),
        )
        for r in ranks
    ]
    a_cat = np.concatenate([a for a, _ in adapters], axis=1)
    b_cat = np.concatenate([b for _, b in adapters], axis=0)
    want = np.asarray(sequential_adapters(x, adapters))
    got_ref = np.asarray(ref.fused_adapter_ref(x, a_cat, b_cat))
    got_kernel = np.asarray(fused_adapter(x, a_cat, b_cat, block_m=8))
    np.testing.assert_allclose(got_ref, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_kernel, want, rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(1, 12),
    k=st.integers(8, 48),
    n=st.integers(8, 48),
    r=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_salr_linear_matches_ref(m, k, n, r, seed):
    rng = np.random.default_rng(seed)
    w = sparse_matrix(rng, k, n, 0.5)
    x = rng.normal(size=(m, k)).astype(np.float32)
    a = rng.normal(size=(k, r)).astype(np.float32) * 0.3
    b = rng.normal(size=(r, n)).astype(np.float32) * 0.3
    words, vals, offs = encode_bitmap(w)
    want = np.asarray(ref.salr_linear_ref(x, w, a, b))
    got = np.asarray(
        salr_linear(x, words, vals, offs, a, b, n, block_m=8, block_k=16)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(want, x @ w + (x @ a) @ b, rtol=1e-4, atol=1e-4)


@given(
    rows=st.integers(2, 40),
    cols_half=st.integers(2, 24),
    block=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_nf4_dequant_kernel_matches_ref(rows, cols_half, block, seed):
    rng = np.random.default_rng(seed)
    cols = cols_half * 2
    codes = rng.integers(0, 256, size=(rows * cols) // 2, dtype=np.uint8)
    scales = rng.uniform(0.1, 3.0, size=(rows * cols + block - 1) // block).astype(
        np.float32
    )
    want = np.asarray(ref.nf4_dequant_ref(codes, scales, rows, cols, block))
    got = np.asarray(
        nf4_dequant(codes.reshape(rows, cols // 2), scales, rows, cols, block, block_k=8)
    )
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_nf4_matmul_composes():
    rng = np.random.default_rng(7)
    rows, cols, block, m = 32, 16, 64, 5
    codes = rng.integers(0, 256, size=(rows * cols) // 2, dtype=np.uint8)
    scales = rng.uniform(0.1, 1.0, size=(rows * cols) // block).astype(np.float32)
    x = rng.normal(size=(m, rows)).astype(np.float32)
    w = np.asarray(ref.nf4_dequant_ref(codes, scales, rows, cols, block))
    got = np.asarray(
        nf4_matmul(x, codes.reshape(rows, cols // 2), scales, rows, cols, block)
    )
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


def test_bitmap_codebook_agrees_with_rust_layout():
    """Bit t of word b covers column 32b + t — the exact layout rust's
    BitmapMatrix serializes via u8 masks (little-endian bit order)."""
    w = np.zeros((1, 40), dtype=np.float32)
    w[0, 0] = 1.0
    w[0, 7] = 2.0
    w[0, 33] = 3.0
    words, vals, offs = encode_bitmap(w)
    assert words[0, 0] == (1 | (1 << 7))
    assert words[0, 1] == (1 << 1)
    np.testing.assert_array_equal(vals[:3], [1.0, 2.0, 3.0])
    dec = np.asarray(ref.bitmap_decode_ref(words, vals, offs, 40))
    np.testing.assert_allclose(dec, w)


def test_decode_all_zero_and_all_dense_rows():
    w = np.zeros((4, 16), dtype=np.float32)
    w[2] = np.arange(1, 17, dtype=np.float32)
    words, vals, offs = encode_bitmap(w)
    dec = np.asarray(bitmap_decode(words, vals, offs, 16, block_k=2))
    np.testing.assert_allclose(dec, w)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
