"""AOT path checks: HLO text is parseable-shaped, manifest is consistent
with the lowering, and an HLO artifact reproduces the jitted numerics when
executed through xla_client (the same engine the rust PJRT client embeds).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.config import ModelConfig

CFG = ModelConfig(
    name="aot-test", d_model=32, n_layers=1, n_heads=2, d_ff=64,
    max_seq_len=8, rank=4, residual_rank=4, batch_size=2, vocab_size=32,
)


def test_hlo_text_structure():
    lowered, ins, outs = aot.lower_eval(CFG, "lora", CFG.batch_size)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # One parameter per manifest input.
    assert text.count("parameter(") >= len(ins)


def test_manifest_io_matches_flattening():
    """The manifest's input order must equal jax's pytree flatten order."""
    lowered, ins, outs = aot.lower_finetune(CFG, "salr")
    # jax flattens dicts sorted by key; reconstruct the expected order.
    fkeys = M.frozen_keys(CFG, "salr")
    tkeys = M.trainable_keys(CFG, "salr")
    want = (
        [f"frozen:{k}" for k in fkeys]
        + [f"train:{k}" for k in tkeys]
        + [f"m:{k}" for k in tkeys]
        + [f"v:{k}" for k in tkeys]
        + ["t", "tokens", "loss_mask", "lr", "eta"]
    )
    assert [e["name"] for e in ins] == want
    want_out = (
        [f"train:{k}" for k in tkeys]
        + [f"m:{k}" for k in tkeys]
        + [f"v:{k}" for k in tkeys]
        + ["loss"]
    )
    assert [e["name"] for e in outs] == want_out
    # Input arity matches the lowered computation.
    text = aot.to_hlo_text(lowered)
    assert text.count("parameter(") >= len(ins)


def test_hlo_roundtrip_executes_same_numbers(tmp_path):
    """Lower eval to HLO text, re-parse + compile with xla_client, compare
    against the jitted reference — the exact path rust's runtime takes."""
    from jax._src.lib import xla_client as xc

    step = M.eval_logits(CFG, "lora")
    frozen = M.init_base_params(CFG, jax.random.PRNGKey(0))
    tr = M.init_adapters(CFG, jax.random.PRNGKey(1), False)
    # Nonzero B so adapters matter.
    tr = {
        k: (jax.random.normal(jax.random.PRNGKey(i), x.shape) * 0.1).astype(
            jnp.float32
        )
        for i, (k, x) in enumerate(sorted(tr.items()))
    }
    tokens = jax.random.randint(
        jax.random.PRNGKey(2), (CFG.batch_size, CFG.max_seq_len), 0, CFG.vocab_size
    )
    want = np.asarray(jax.jit(step)(frozen, tr, tokens))

    lowered = jax.jit(step).lower(frozen, tr, tokens)
    text = aot.to_hlo_text(lowered)

    client = xc.make_cpu_client()
    # Round-trip through XlaComputation (the object whose as_hlo_text() is
    # the artifact format), back to MLIR, compile, execute. The HLO-*text*
    # parse+execute leg is covered by rust's runtime integration tests.
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    mlir_text = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    exe = client.compile_and_load(
        mlir_text,
        client.devices(),
        xc.CompileOptions(),
    )
    flat = (
        [np.asarray(frozen[k]) for k in sorted(frozen)]
        + [np.asarray(tr[k]) for k in sorted(tr)]
        + [np.asarray(tokens)]
    )
    out = exe.execute_sharded([client.buffer_from_pyval(a) for a in flat])
    got = np.asarray(out.disassemble_into_single_device_arrays()[0][0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert len(text) > 1000


def test_built_manifest_if_present():
    """If `make artifacts` has run, sanity-check the real manifest."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        man = json.load(f)
    assert man["format"] == 1
    names = {a["name"] for a in man["artifacts"]}
    for required in (
        "pretrain_tiny",
        "train_salr_tiny",
        "train_losa_tiny",
        "eval_salr_tiny",
        "salr_kernel_pallas_tiny",
    ):
        assert required in names, required
    for a in man["artifacts"]:
        f_ = os.path.join(os.path.dirname(path), a["file"])
        assert os.path.exists(f_), a["file"]
        for io in a["inputs"] + a["outputs"]:
            assert io["dtype"] in ("f32", "i32", "u32")


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
