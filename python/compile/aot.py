"""AOT lowering: JAX → HLO text artifacts + manifest for the rust runtime.

HLO *text* (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProtos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; the rust binary is self-contained after.

Usage:
    python -m compile.aot --out ../artifacts [--configs tiny,small]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .config import CONFIGS, ModelConfig


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dict_specs(cfg: ModelConfig, keys, shapes_of):
    return {k: _spec(shapes_of(k)) for k in keys}


def _base_shape(cfg: ModelConfig, key: str):
    if key == "embed":
        return (cfg.vocab_size, cfg.d_model)
    if key == "pos_embed":
        return (cfg.max_seq_len, cfg.d_model)
    if key == "lm_head":
        return (cfg.d_model, cfg.vocab_size)
    if key.endswith(("attn_norm", "mlp_norm")) or key == "final_norm":
        return (cfg.d_model,)
    if key.endswith(".mask"):
        lin = key.split(".")[1]
        return cfg.linear_shape(lin)
    lin = key.split(".")[1]
    return cfg.linear_shape(lin)


def _trainable_shape(cfg: ModelConfig, key: str):
    name, kind = key.rsplit(".", 1)
    lin = name.split(".")[1]
    d_in, d_out = cfg.linear_shape(lin)
    if kind == "lora_a":
        return (d_in, cfg.rank)
    if kind == "lora_b":
        return (cfg.rank, d_out)
    if kind == "res_a":
        return (d_in, cfg.residual_rank)
    if kind == "res_b":
        return (cfg.residual_rank, d_out)
    raise ValueError(key)


def _io_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def lower_pretrain(cfg: ModelConfig):
    step = M.pretrain_step(cfg)
    base_keys = M.frozen_keys(cfg, "lora")  # base params only (no masks)
    params = _dict_specs(cfg, base_keys, lambda k: _base_shape(cfg, k))
    m = params
    v = params
    tokens = _spec((cfg.batch_size, cfg.max_seq_len), jnp.int32)
    mask = _spec((cfg.batch_size, cfg.max_seq_len))
    t = _spec(())
    lr = _spec(())
    lowered = jax.jit(step, keep_unused=True).lower(params, m, v, t, tokens, mask, lr)
    inputs = (
        [_io_entry(f"param:{k}", _base_shape(cfg, k)) for k in base_keys]
        + [_io_entry(f"m:{k}", _base_shape(cfg, k)) for k in base_keys]
        + [_io_entry(f"v:{k}", _base_shape(cfg, k)) for k in base_keys]
        + [
            _io_entry("t", ()),
            _io_entry("tokens", (cfg.batch_size, cfg.max_seq_len), "i32"),
            _io_entry("loss_mask", (cfg.batch_size, cfg.max_seq_len)),
            _io_entry("lr", ()),
        ]
    )
    outputs = (
        [_io_entry(f"param:{k}", _base_shape(cfg, k)) for k in base_keys]
        + [_io_entry(f"m:{k}", _base_shape(cfg, k)) for k in base_keys]
        + [_io_entry(f"v:{k}", _base_shape(cfg, k)) for k in base_keys]
        + [_io_entry("loss", ())]
    )
    return lowered, inputs, outputs


def lower_finetune(cfg: ModelConfig, variant: str):
    step = M.finetune_step(cfg, variant)
    fkeys = M.frozen_keys(cfg, variant)
    tkeys = M.trainable_keys(cfg, variant)
    frozen = _dict_specs(cfg, fkeys, lambda k: _base_shape(cfg, k))
    trainable = _dict_specs(cfg, tkeys, lambda k: _trainable_shape(cfg, k))
    tokens = _spec((cfg.batch_size, cfg.max_seq_len), jnp.int32)
    mask = _spec((cfg.batch_size, cfg.max_seq_len))
    scalar = _spec(())
    lowered = jax.jit(step, keep_unused=True).lower(
        frozen, trainable, trainable, trainable, scalar, tokens, mask, scalar, scalar
    )
    inputs = (
        [_io_entry(f"frozen:{k}", _base_shape(cfg, k)) for k in fkeys]
        + [_io_entry(f"train:{k}", _trainable_shape(cfg, k)) for k in tkeys]
        + [_io_entry(f"m:{k}", _trainable_shape(cfg, k)) for k in tkeys]
        + [_io_entry(f"v:{k}", _trainable_shape(cfg, k)) for k in tkeys]
        + [
            _io_entry("t", ()),
            _io_entry("tokens", (cfg.batch_size, cfg.max_seq_len), "i32"),
            _io_entry("loss_mask", (cfg.batch_size, cfg.max_seq_len)),
            _io_entry("lr", ()),
            _io_entry("eta", ()),
        ]
    )
    outputs = (
        [_io_entry(f"train:{k}", _trainable_shape(cfg, k)) for k in tkeys]
        + [_io_entry(f"m:{k}", _trainable_shape(cfg, k)) for k in tkeys]
        + [_io_entry(f"v:{k}", _trainable_shape(cfg, k)) for k in tkeys]
        + [_io_entry("loss", ())]
    )
    return lowered, inputs, outputs


def lower_eval(cfg: ModelConfig, variant: str, batch: int):
    step = M.eval_logits(cfg, variant)
    fkeys = M.frozen_keys(cfg, variant)
    tkeys = M.trainable_keys(cfg, variant)
    frozen = _dict_specs(cfg, fkeys, lambda k: _base_shape(cfg, k))
    trainable = _dict_specs(cfg, tkeys, lambda k: _trainable_shape(cfg, k))
    tokens = _spec((batch, cfg.max_seq_len), jnp.int32)
    lowered = jax.jit(step, keep_unused=True).lower(frozen, trainable, tokens)
    inputs = (
        [_io_entry(f"frozen:{k}", _base_shape(cfg, k)) for k in fkeys]
        + [_io_entry(f"train:{k}", _trainable_shape(cfg, k)) for k in tkeys]
        + [_io_entry("tokens", (batch, cfg.max_seq_len), "i32")]
    )
    outputs = [
        _io_entry("logits", (batch, cfg.max_seq_len, cfg.vocab_size))
    ]
    return lowered, inputs, outputs


def lower_salr_kernel(cfg: ModelConfig):
    """Pallas SALR-linear microbench artifact (interpret-mode kernel)."""
    d_in, d_out = cfg.d_model, cfg.d_ff
    nnz_pad = d_in * d_out  # worst-case padding, runtime passes real nnz
    rank_total = cfg.rank + cfg.residual_rank
    m_rows = cfg.batch_size * cfg.max_seq_len
    wpr = (d_out + 31) // 32

    def fn(x, words, values, offs, a_cat, b_cat):
        return M.salr_linear_pallas(x, words, values, offs, a_cat, b_cat, d_out)

    lowered = jax.jit(fn, keep_unused=True).lower(
        _spec((m_rows, d_in)),
        _spec((d_in, wpr), jnp.uint32),
        _spec((nnz_pad,)),
        _spec((d_in,), jnp.int32),
        _spec((d_in, rank_total)),
        _spec((rank_total, d_out)),
    )
    inputs = [
        _io_entry("x", (m_rows, d_in)),
        _io_entry("mask_words", (d_in, wpr), "u32"),
        _io_entry("values", (nnz_pad,)),
        _io_entry("row_offsets", (d_in,), "i32"),
        _io_entry("a_cat", (d_in, rank_total)),
        _io_entry("b_cat", (rank_total, d_out)),
    ]
    outputs = [_io_entry("y", (m_rows, d_out))]
    return lowered, inputs, outputs


# Artifact plan: which steps to lower per config.
PLAN = {
    "tiny": [
        "pretrain",
        "train_lora",
        "train_salr",
        "train_losa",
        "train_sparselora",
        "eval_lora",
        "eval_salr",
        "eval_losa",
        "salr_kernel_pallas",
    ],
    "small": ["pretrain", "train_lora", "train_salr", "eval_lora", "eval_salr"],
}


def build(outdir: str, config_names):
    os.makedirs(outdir, exist_ok=True)
    manifest = {"format": 1, "configs": {}, "artifacts": []}
    for cname in config_names:
        cfg = CONFIGS[cname]
        manifest["configs"][cname] = cfg.to_dict()
        for item in PLAN[cname]:
            if item == "pretrain":
                lowered, ins, outs = lower_pretrain(cfg)
            elif item.startswith("train_"):
                lowered, ins, outs = lower_finetune(cfg, item[len("train_"):])
            elif item.startswith("eval_"):
                lowered, ins, outs = lower_eval(cfg, item[len("eval_"):], cfg.batch_size)
            elif item == "salr_kernel_pallas":
                lowered, ins, outs = lower_salr_kernel(cfg)
            else:
                raise ValueError(item)
            name = f"{item}_{cname}"
            path = f"{name}.hlo.txt"
            text = to_hlo_text(lowered)
            with open(os.path.join(outdir, path), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "config": cname,
                    "kind": item,
                    "file": path,
                    "inputs": ins,
                    "outputs": outs,
                }
            )
            print(f"lowered {name}: {len(text) / 1e6:.2f} MB, "
                  f"{len(ins)} inputs, {len(outs)} outputs")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {os.path.join(outdir, 'manifest.json')} "
          f"({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    args = ap.parse_args()
    build(args.out, [c for c in args.configs.split(",") if c])


if __name__ == "__main__":
    main()
