"""Layer 2: the JAX transformer with SALR-adapted linear layers.

A decoder-only transformer (RMSNorm, causal MHA with learned positions,
GELU MLP) whose linear layers are adapted according to one of the paper's
variants:

* ``dense``       — plain ``x @ W`` (pretraining / pretrained eval);
* ``lora``        — ``x @ W0 + s·(x A) B`` (frozen W0, trainable A,B).
  Feeding a *pruned* W0 gives the DeepSparse-like baseline;
* ``salr``        — ``x @ Ŵ + (x A_cat) B_cat`` where A_cat/B_cat stack the
  LoRA adapter (scaled) and the sparsity-preservation residual adapter
  (paper: adapter concatenation). Ŵ is the statically pruned base weight
  (Theorem 2, Method 1); the residual adapter is initialized from the
  truncated SVD of the pruning residual (Theorem 3) and trained with the
  Theorem-4 step size;
* ``losa``        — ``x @ ((W0 + s·A B) ⊙ M)`` with a dynamic mask M on the
  merged weight (Theorem 2, Method 3) — the paper's LoSA baseline. Note the
  two dense GEMMs (ΔW = A·B materialized) this forces per layer: that is
  exactly the fine-tuning inefficiency Table 3 charges LoSA with;
* ``sparselora``  — contextual sparsity on the *base* branch during
  training (per-token top-k input channels), dense deployment — the
  SparseLoRA baseline (training-only wins).

All steps are AOT-lowered by ``aot.py``; the rust coordinator executes the
HLO and never runs python.
"""

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig

VARIANTS = ("dense", "lora", "salr", "losa", "sparselora")
# Trainable-key suffixes for the residual (Theorem-4 SGD) vs LoRA (Adam).
RES_SUFFIXES = (".res_a", ".res_b")


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def init_base_params(cfg: ModelConfig, key) -> dict:
    """Dense base parameters (the 'pretrained model' to be)."""
    keys = jax.random.split(key, 4 + cfg.n_layers)
    p = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02,
        "pos_embed": jax.random.normal(keys[1], (cfg.max_seq_len, cfg.d_model)) * 0.02,
        "lm_head": jax.random.normal(keys[2], (cfg.d_model, cfg.vocab_size)) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[3 + i], 6)
        p[f"layer{i}.attn_norm"] = jnp.ones((cfg.d_model,))
        p[f"layer{i}.mlp_norm"] = jnp.ones((cfg.d_model,))
        for j, lin in enumerate(("wq", "wk", "wv", "wo", "w_in", "w_out")):
            d_in, d_out = cfg.linear_shape(lin)
            scale = d_in ** -0.5
            p[f"layer{i}.{lin}"] = jax.random.normal(lk[j], (d_in, d_out)) * scale
    return {k: v.astype(jnp.float32) for k, v in p.items()}


def init_adapters(cfg: ModelConfig, key, with_residual: bool) -> dict:
    """LoRA adapters (A ~ N(0, 1/d_in), B = 0) and, optionally, residual
    adapter placeholders (overwritten by the SVD of the pruning residual
    on the rust side before fine-tuning starts)."""
    t = {}
    names = cfg.adapted_layers()
    keys = jax.random.split(key, len(names))
    for k_, name in zip(keys, names):
        lin = name.split(".")[1]
        d_in, d_out = cfg.linear_shape(lin)
        t[f"{name}.lora_a"] = (
            jax.random.normal(k_, (d_in, cfg.rank)) * (d_in ** -0.5)
        ).astype(jnp.float32)
        t[f"{name}.lora_b"] = jnp.zeros((cfg.rank, d_out), jnp.float32)
        if with_residual:
            t[f"{name}.res_a"] = jnp.zeros((d_in, cfg.residual_rank), jnp.float32)
            t[f"{name}.res_b"] = jnp.zeros((cfg.residual_rank, d_out), jnp.float32)
    return t


def init_masks(cfg: ModelConfig) -> dict:
    """All-ones masks (stand-ins; rust supplies the real LoSA masks)."""
    m = {}
    for name in cfg.adapted_layers():
        lin = name.split(".")[1]
        m[f"{name}.mask"] = jnp.ones(cfg.linear_shape(lin), jnp.float32)
    return m


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _rms_norm(x, gamma, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def _rope(x, positions):
    """Rotary position embedding (half-split layout).

    x: [B, S, H, hd]; positions: int[S]. Mirrored bit-for-bit by the rust
    engine (`infer::engine::apply_rope`).
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _ctx_sparse_input(x, keep_frac):
    """SparseLoRA-style contextual sparsity: per token, keep the largest
    |x| channels (the base-branch GEMM then touches only those weight
    rows). Gradient flows through the kept values; the mask itself is not
    differentiated."""
    d = x.shape[-1]
    k = max(1, int(d * keep_frac))
    # The mask is non-differentiable; cut the tangent before the sort so
    # the selection machinery never enters the backward graph.
    xa = jax.lax.stop_gradient(jnp.abs(x))
    thresh = jnp.sort(xa, axis=-1)[..., d - k]
    mask = (xa >= thresh[..., None]).astype(x.dtype)
    return x * mask


def _adapted_linear(cfg, variant, x, w, tr, masks, name):
    """One SALR/LoRA/LoSA linear. ``x``: [B, S, d_in] (or [N, d_in])."""
    s = cfg.lora_scaling
    if variant == "dense":
        return x @ w
    a = tr[f"{name}.lora_a"]
    b = tr[f"{name}.lora_b"]
    if variant == "lora":
        return x @ w + ((x @ a) @ b) * s
    if variant == "salr":
        # Adapter concatenation (paper): A_cat = [s·A ‖ A_res],
        # B_cat = [B ; B_res] — one fused rank-(r+r_res) GEMM pair.
        a_cat = jnp.concatenate([a * s, tr[f"{name}.res_a"]], axis=1)
        b_cat = jnp.concatenate([b, tr[f"{name}.res_b"]], axis=0)
        return x @ w + (x @ a_cat) @ b_cat
    if variant == "losa":
        # Dynamic mask on the merged weight: two dense GEMMs (ΔW = A B,
        # then X (W+ΔW)⊙M) — LoSA's fine-tuning cost structure.
        w_eff = (w + (a @ b) * s) * masks[f"{name}.mask"]
        return x @ w_eff
    if variant == "sparselora":
        x_sp = _ctx_sparse_input(x, cfg.ctx_keep)
        return x_sp @ w + ((x @ a) @ b) * s
    raise ValueError(f"unknown variant {variant}")


def forward(cfg: ModelConfig, variant: str, frozen: dict, tr: dict, tokens):
    """Token logits. ``tokens``: int32[B, S] → f32[B, S, vocab]."""
    b, s_len = tokens.shape
    masks = frozen  # losa masks live alongside frozen params
    x = frozen["embed"][tokens] + frozen["pos_embed"][None, :s_len, :]
    causal = jnp.tril(jnp.ones((s_len, s_len), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(cfg.n_layers):
        h = _rms_norm(x, frozen[f"layer{i}.attn_norm"])
        q = _adapted_linear(cfg, variant, h, frozen[f"layer{i}.wq"], tr, masks, f"layer{i}.wq")
        k = _adapted_linear(cfg, variant, h, frozen[f"layer{i}.wk"], tr, masks, f"layer{i}.wk")
        v = _adapted_linear(cfg, variant, h, frozen[f"layer{i}.wv"], tr, masks, f"layer{i}.wv")
        hd = cfg.head_dim
        positions = jnp.arange(s_len)
        q = _rope(q.reshape(b, s_len, cfg.n_heads, hd), positions).transpose(0, 2, 1, 3)
        k = _rope(k.reshape(b, s_len, cfg.n_heads, hd), positions).transpose(0, 2, 1, 3)
        v = v.reshape(b, s_len, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (hd ** -0.5)
        att = jnp.where(causal[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(b, s_len, cfg.d_model)
        o = _adapted_linear(cfg, variant, o, frozen[f"layer{i}.wo"], tr, masks, f"layer{i}.wo")
        x = x + o
        h = _rms_norm(x, frozen[f"layer{i}.mlp_norm"])
        h = _adapted_linear(cfg, variant, h, frozen[f"layer{i}.w_in"], tr, masks, f"layer{i}.w_in")
        h = jax.nn.gelu(h)
        h = _adapted_linear(cfg, variant, h, frozen[f"layer{i}.w_out"], tr, masks, f"layer{i}.w_out")
        x = x + h
    x = _rms_norm(x, frozen["final_norm"])
    return x @ frozen["lm_head"]


def loss_fn(cfg, variant, frozen, tr, tokens, loss_mask):
    """Shifted next-token cross entropy, averaged over unmasked targets."""
    logits = forward(cfg, variant, frozen, tr, tokens)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    mask = loss_mask[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Optimizer + train steps
# ---------------------------------------------------------------------------

def _adam_update(p, g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * (g * g)
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    return p - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def pretrain_step(cfg: ModelConfig):
    """Full-parameter Adam pretraining step (builds the 'pretrained' base).

    Signature: (params, m, v, t, tokens, loss_mask, lr) ->
               (params', m', v', loss)
    """

    def step(params, m, v, t, tokens, loss_mask, lr):
        empty = {}
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, "dense", p, empty, tokens, loss_mask)
        )(params)
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            new_p[k], new_m[k], new_v[k] = _adam_update(
                params[k], grads[k], m[k], v[k], t, lr
            )
        return new_p, new_m, new_v, loss

    return step


def finetune_step(cfg: ModelConfig, variant: str):
    """Adapter fine-tuning step for ``variant``.

    Signature: (frozen, trainable, m, v, t, tokens, loss_mask, lr, eta) ->
               (trainable', m', v', loss)

    LoRA adapters update with Adam(lr); the SALR residual adapters update
    with plain gradient descent at the Theorem-4 step size ``eta``
    (``eta = 0`` freezes the residual — the Table-5 ablation).
    """
    assert variant in ("lora", "salr", "losa", "sparselora")

    def step(frozen, trainable, m, v, t, tokens, loss_mask, lr, eta):
        loss, grads = jax.value_and_grad(
            lambda tr: loss_fn(cfg, variant, frozen, tr, tokens, loss_mask)
        )(trainable)
        new_t, new_m, new_v = {}, {}, {}
        for k in trainable:
            if k.endswith(RES_SUFFIXES):
                # Theorem 4: convex residual subproblem — SGD at
                # eta <= 1/σ_max(X)² (estimated by power iteration in rust).
                new_t[k] = trainable[k] - eta * grads[k]
                new_m[k], new_v[k] = m[k], v[k]
            else:
                new_t[k], new_m[k], new_v[k] = _adam_update(
                    trainable[k], grads[k], m[k], v[k], t, lr
                )
        return new_t, new_m, new_v, loss

    return step


def eval_logits(cfg: ModelConfig, variant: str):
    """Inference forward: (frozen, trainable, tokens) -> logits."""

    def step(frozen, trainable, tokens):
        return forward(cfg, variant, frozen, trainable, tokens)

    return step


# ---------------------------------------------------------------------------
# Pallas-kernel forward (microbench artifact)
# ---------------------------------------------------------------------------

def salr_linear_pallas(x, mask_words, values, row_offsets, a_cat, b_cat, cols):
    """The L1 SALR kernel wrapped for AOT lowering (interpret-mode pallas
    lowers to plain HLO the rust CPU client can execute)."""
    from .kernels.salr_matmul import salr_linear

    return salr_linear(x, mask_words, values, row_offsets, a_cat, b_cat, cols)


# ---------------------------------------------------------------------------
# Canonical flat ordering (shared with the manifest / rust)
# ---------------------------------------------------------------------------

def sorted_keys(d: dict):
    """jax flattens dicts in sorted-key order; make that explicit."""
    return sorted(d.keys())


def flatten_dict(d: dict):
    return [d[k] for k in sorted_keys(d)]


@functools.lru_cache(maxsize=None)
def frozen_keys(cfg: ModelConfig, variant: str):
    """Names of the frozen inputs for a variant, sorted."""
    base = init_base_params(cfg, jax.random.PRNGKey(0))
    keys = set(base.keys())
    if variant == "losa":
        keys |= set(init_masks(cfg).keys())
    return sorted(keys)


@functools.lru_cache(maxsize=None)
def trainable_keys(cfg: ModelConfig, variant: str):
    ad = init_adapters(cfg, jax.random.PRNGKey(0), with_residual=(variant == "salr"))
    return sorted(ad.keys())
