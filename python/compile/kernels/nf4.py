"""Pallas kernel: NF4 dequantize (+ matmul) for QSALR (Table 6).

4-bit NormalFloat codes are unpacked (two per byte), mapped through the
16-entry codebook and rescaled by per-block absmax — then fed to the MXU.
TPU mapping: the codebook lookup is a 16-wide gather, a native VPU
operation; the unpack is shift/AND vector work, overlapped with the dot
via the grid pipeline as in ``bitmap_decode``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NF4_CODEBOOK


def _dequant_rows(codes_rows, scales, codebook, row0, cols, block):
    """Dequantize a panel of rows. ``codes_rows``: uint8[bk, cols//2]."""
    bk = codes_rows.shape[0]
    lo = (codes_rows & 0x0F).astype(jnp.int32)
    hi = (codes_rows >> 4).astype(jnp.int32)
    idx = jnp.stack([lo, hi], axis=2).reshape(bk, cols)
    vals = codebook[idx]
    # Global element index of each entry → block scale index.
    elem = (row0 + jnp.arange(bk))[:, None] * cols + jnp.arange(cols)[None, :]
    scale = scales[jnp.clip(elem // block, 0, scales.shape[0] - 1)]
    return vals * scale


def _dequant_kernel(codes_ref, scales_ref, codebook_ref, o_ref, *, cols, block, bk):
    row0 = pl.program_id(0) * bk
    o_ref[...] = _dequant_rows(
        codes_ref[...], scales_ref[...], codebook_ref[...], row0, cols, block
    )


@functools.partial(jax.jit, static_argnames=("rows", "cols", "block", "block_k"))
def nf4_dequant(codes, scales, rows: int, cols: int, block: int, block_k: int = 256):
    """Dequantize row-major packed NF4 codes to dense f32[rows, cols].

    Args:
      codes: uint8[rows, cols//2] packed codes (low nibble first). ``cols``
        must be even (weight matrices here always are).
      scales: f32[ceil(rows*cols/block)] per-block absmax scales.
    """
    assert cols % 2 == 0, "nf4 kernel requires even column count"
    assert codes.shape == (rows, cols // 2), codes.shape
    bk = min(block_k, rows)
    grid = (pl.cdiv(rows, bk),)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, cols=cols, block=block, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, cols // 2), lambda i: (i, 0)),
            pl.BlockSpec(scales.shape, lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bk, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(codes, scales, NF4_CODEBOOK)


@functools.partial(jax.jit, static_argnames=("rows", "cols", "block"))
def nf4_matmul(x, codes, scales, rows: int, cols: int, block: int):
    """``y = x @ dequant(codes)`` (dequant kernel + XLA dot)."""
    w = nf4_dequant(codes, scales, rows, cols, block)
    return x @ w
