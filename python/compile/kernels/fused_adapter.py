"""Pallas kernel: fused concatenated-adapter GEMM.

The paper replaces 2n small adapter GEMMs with two larger ones on the
stacked matrices ``A_cat [k, n*r]`` / ``B_cat [n*r, n_out]``. On TPU the
payoff is MXU occupancy: a rank-8 sliver (k×8 @ 8×n) cannot fill the
128×128 systolic array, while the concatenated rank (n·r ≥ 128 for the
paper's rank-64 + residual) can.

Kernel mapping (paper GPU → TPU):
  * thread-block tile over M            → grid over M tiles (BlockSpec);
  * shared-memory staging of A_i        → A_cat/B_cat resident in VMEM;
  * WMMA tensor-core MACs               → ``jnp.dot`` inside the kernel
                                           (lowers to MXU matmuls);
  * kernel-launch amortization          → single pallas_call.

VMEM budget at the default tile (bm=128, k≤1536, nr≤192, n≤1536, f32):
  x tile 128·1536·4 = 768 KiB, A_cat 1536·192·4 = 1.15 MiB,
  B_cat 192·1536·4 = 1.15 MiB, out 128·1536·4 = 768 KiB  → ≈3.9 MiB ≤ 16 MiB.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; numerics are validated against ``ref.fused_adapter_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, o_ref):
    # u = x_tile @ A_cat : [bm, nr] — first fused GEMM.
    u = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    # o = u @ B_cat : [bm, n_out] — second fused GEMM.
    o_ref[...] = jnp.dot(u, b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m",))
def fused_adapter(x, a_cat, b_cat, block_m: int = 128):
    """Compute ``(x @ a_cat) @ b_cat`` with an M-tiled Pallas kernel.

    Args:
      x: f32[m, k] shared adapter input.
      a_cat: f32[k, nr] stacked A factors.
      b_cat: f32[nr, n] stacked B factors.
      block_m: M-tile height (grid dimension).
    """
    m, k = x.shape
    nr, n = b_cat.shape
    assert a_cat.shape == (k, nr), (a_cat.shape, (k, nr))
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, nr), lambda i: (0, 0)),
            pl.BlockSpec((nr, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, a_cat, b_cat)


def sequential_adapters(x, adapters):
    """Baseline: apply each (A_i, B_i) separately and sum — the 2n-GEMM
    pattern the concatenation scheme replaces. Used by the ablation bench.
    """
    out = None
    for a_i, b_i in adapters:
        d = (x @ a_i) @ b_i
        out = d if out is None else out + d
    return out
