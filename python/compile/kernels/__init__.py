# L1: Pallas kernels for the paper's compute hot-spots, plus pure-jnp
# oracles (ref.py). All kernels run with interpret=True — the CPU PJRT
# client cannot execute Mosaic custom-calls; TPU mapping rationale lives
# in each module's docstring and DESIGN.md §Hardware-Adaptation.

from . import ref
from .bitmap_decode import bitmap_decode, bitmap_matmul
from .fused_adapter import fused_adapter, sequential_adapters
from .nf4 import nf4_dequant, nf4_matmul
from .salr_matmul import salr_linear

__all__ = [
    "ref",
    "bitmap_decode",
    "bitmap_matmul",
    "fused_adapter",
    "sequential_adapters",
    "nf4_dequant",
    "nf4_matmul",
    "salr_linear",
]
