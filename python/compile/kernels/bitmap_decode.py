"""Pallas kernel: bitmap decode (+ matmul) of sparse base weights.

The paper's deployment format stores the pruned weight as a bitmap plus a
compact value array, reconstructed byte-block-wise with popcount/LUT logic
on CUDA cores. The TPU mapping replaces the byte LUT with vectorized bit
arithmetic over 32-bit words (the VPU has no scalar LUT gather, but a
32-lane shift-and-mask unpack is a native vector op):

  * CUDA byte mask + LUT scatter  → 32-wide shift/AND unpack + prefix-sum
                                    index computation + vector gather;
  * ring-buffer into tensor cores → grid over K-panels; the Pallas
                                    pipeline double-buffers the HBM→VMEM
                                    streaming of (words, values) while the
                                    MXU consumes the previous panel — the
                                    same decode/GEMM overlap, expressed
                                    with BlockSpec instead of CUDA streams.

VMEM at defaults (bk=256 panel rows, n≤1536): words 256·48·4 = 48 KiB,
values (full array resident) ≤ a few MiB at the model's layer sizes,
decoded panel 256·1536·4 = 1.5 MiB, accumulator 128·1536·4 = 768 KiB —
under the 16 MiB budget.

``interpret=True``: validated against ``ref.bitmap_decode_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_block(words, values, row_offsets, cols):
    """Vectorized bitmap decode of a row panel (in-kernel helper)."""
    bk, wpr = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    bits = bits.reshape(bk, wpr * 32)[:, :cols].astype(jnp.int32)
    idx_in_row = jnp.cumsum(bits, axis=1) - bits
    idx = row_offsets[:, None] + idx_in_row
    gathered = values[jnp.clip(idx, 0, values.shape[0] - 1)]
    return jnp.where(bits == 1, gathered, 0.0)


def _decode_kernel(words_ref, values_ref, offs_ref, o_ref, *, cols):
    o_ref[...] = _decode_block(
        words_ref[...], values_ref[...], offs_ref[...], cols
    )


@functools.partial(jax.jit, static_argnames=("cols", "block_k"))
def bitmap_decode(mask_words, values, row_offsets, cols: int, block_k: int = 256):
    """Decode a bitmap-encoded matrix to dense f32[k, cols].

    Args:
      mask_words: uint32[k, wpr] packed bitmap (bit t of word w = column
        32w+t).
      values: f32[nnz_pad] compact values, row-major (padded to any length).
      row_offsets: int32[k] per-row start offset into ``values``.
      cols: static column count.
      block_k: rows decoded per grid step (the K-panel of the pipeline).
    """
    k, wpr = mask_words.shape
    bk = min(block_k, k)
    grid = (pl.cdiv(k, bk),)
    return pl.pallas_call(
        functools.partial(_decode_kernel, cols=cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, wpr), lambda i: (i, 0)),
            pl.BlockSpec(values.shape, lambda i: (0,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bk, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, cols), jnp.float32),
        interpret=True,
    )(mask_words, values, row_offsets)


def _matmul_kernel(
    x_ref, words_ref, values_ref, offs_ref, o_ref, acc_ref, *, cols, k_total, bk
):
    """Decode one K-panel of W, accumulate ``x_panel @ W_panel``.

    Grid = (m tiles, k panels). The accumulator lives in VMEM scratch and
    is flushed on the final K step — the standard Pallas matmul pipeline
    with the bitmap decode fused ahead of the MXU dot. Rows of the final
    ragged panel beyond ``k_total`` carry padding garbage; they are zeroed
    before the dot so the padded x columns never contribute.
    """
    kp = pl.program_id(1)

    @pl.when(kp == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_panel = _decode_block(words_ref[...], values_ref[...], offs_ref[...], cols)
    valid = (kp * bk + jnp.arange(bk)) < k_total
    w_panel = jnp.where(valid[:, None], w_panel, 0.0)
    # Interpret-mode pads ragged blocks with NaN; zero both sides (NaN*0=NaN).
    x_blk = jnp.where(valid[None, :], x_ref[...], 0.0)
    acc_ref[...] += jnp.dot(
        x_blk, w_panel, preferred_element_type=jnp.float32
    )

    @pl.when(kp == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("cols", "block_m", "block_k"))
def bitmap_matmul(
    x,
    mask_words,
    values,
    row_offsets,
    cols: int,
    block_m: int = 128,
    block_k: int = 256,
):
    """``y[m, cols] = x[m, k] @ decode(bitmap)`` with K-panel pipelining.

    The kernel analogue of rust's two-stage pipeline: each grid step
    decodes one K-panel (stage 1) and feeds it to the MXU dot (stage 2);
    Pallas double-buffers the next panel's HBM→VMEM copies behind the
    current dot.
    """
    m, k = x.shape
    kw, wpr = mask_words.shape
    assert kw == k, (kw, k)
    bm = min(block_m, m)
    bk = min(block_k, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_matmul_kernel, cols=cols, k_total=k, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kp: (i, kp)),
            pl.BlockSpec((bk, wpr), lambda i, kp: (kp, 0)),
            pl.BlockSpec(values.shape, lambda i, kp: (0,)),
            pl.BlockSpec((bk,), lambda i, kp: (kp,)),
        ],
        out_specs=pl.BlockSpec((bm, cols), lambda i, kp: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, cols), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, cols), jnp.float32)],
        interpret=True,
    )(x, mask_words, values, row_offsets)
