"""Pallas kernel: the complete SALR linear layer.

``y = x @ Ŵ + (x @ A_cat) @ B_cat`` — sparse pruned base weight (bitmap
decoded per K-panel) plus the fused concatenated adapters (LoRA +
sparsity-preservation residual), in one kernel.

This is the paper's serving hot spot: the adapter GEMM executes on the
first grid step while the first weight panel streams in ("the LoRA module
participates in GEMM computation" during decode), then each subsequent
step overlaps panel decode with the MXU dot via the Pallas pipeline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitmap_decode import _decode_block


def _salr_kernel(
    x_ref, xfull_ref, words_ref, values_ref, offs_ref, a_ref, b_ref, o_ref,
    acc_ref, *, cols, k_total, bk
):
    kp = pl.program_id(1)

    @pl.when(kp == 0)
    def _init():
        # Stage overlap: the fused adapter update is computed while the
        # first sparse panel decodes (on TPU both issue; the MXU dot of the
        # adapters hides the VPU decode latency). The adapter contracts the
        # full K dimension, so it reads the unblocked x view.
        u = jnp.dot(xfull_ref[...], a_ref[...], preferred_element_type=jnp.float32)
        acc_ref[...] = jnp.dot(u, b_ref[...], preferred_element_type=jnp.float32)

    w_panel = _decode_block(words_ref[...], values_ref[...], offs_ref[...], cols)
    # Zero padded rows of a ragged final panel (see bitmap_decode).
    valid = (kp * bk + jnp.arange(bk)) < k_total
    w_panel = jnp.where(valid[:, None], w_panel, 0.0)
    # Interpret-mode pads ragged blocks with NaN; zero both sides (NaN*0=NaN).
    x_blk = jnp.where(valid[None, :], x_ref[...], 0.0)
    acc_ref[...] += jnp.dot(
        x_blk, w_panel, preferred_element_type=jnp.float32
    )

    @pl.when(kp == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("cols", "block_m", "block_k"))
def salr_linear(
    x,
    mask_words,
    values,
    row_offsets,
    a_cat,
    b_cat,
    cols: int,
    block_m: int = 128,
    block_k: int = 256,
):
    """Full SALR linear: sparse base + fused adapters, K-panel pipelined.

    Args:
      x: f32[m, k] input activations.
      mask_words/values/row_offsets: bitmap encoding of Ŵ[k, cols].
      a_cat: f32[k, nr] stacked adapter A factors (LoRA ‖ residual).
      b_cat: f32[nr, cols] stacked adapter B factors.
      cols: static output width.
    """
    m, k = x.shape
    nr = a_cat.shape[1]
    assert a_cat.shape == (k, nr)
    assert b_cat.shape == (nr, cols)
    bm = min(block_m, m)
    bk = min(block_k, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(k, bk))
    wpr = mask_words.shape[1]
    return pl.pallas_call(
        functools.partial(_salr_kernel, cols=cols, k_total=k, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kp: (i, kp)),
            pl.BlockSpec((bm, k), lambda i, kp: (i, 0)),
            pl.BlockSpec((bk, wpr), lambda i, kp: (kp, 0)),
            pl.BlockSpec(values.shape, lambda i, kp: (0,)),
            pl.BlockSpec((bk,), lambda i, kp: (kp,)),
            pl.BlockSpec((k, nr), lambda i, kp: (0, 0)),
            pl.BlockSpec((nr, cols), lambda i, kp: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, cols), lambda i, kp: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, cols), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, cols), jnp.float32)],
        interpret=True,
    )(x, x, mask_words, values, row_offsets, a_cat, b_cat)
