"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each kernel in this package has a
matching ``*_ref`` here, and ``python/tests/test_kernels.py`` sweeps
shapes/seeds (hypothesis) asserting ``assert_allclose`` between the two.
The L2 model calls these by default (they lower to clean fused HLO); the
Pallas implementations demonstrate the TPU kernel mapping and are lowered
into dedicated microbench artifacts.
"""

import jax.numpy as jnp

# The standard NF4 codebook (QLoRA), kept in sync with rust's
# ``quant::nf4::NF4_CODEBOOK``.
NF4_CODEBOOK = jnp.array(
    [
        -1.0,
        -0.6961928009986877,
        -0.5250730514526367,
        -0.39491748809814453,
        -0.28444138169288635,
        -0.18477343022823334,
        -0.09105003625154495,
        0.0,
        0.07958029955625534,
        0.16093020141124725,
        0.24611230194568634,
        0.33791524171829224,
        0.44070982933044434,
        0.5626170039176941,
        0.7229568362236023,
        1.0,
    ],
    dtype=jnp.float32,
)


def bitmap_decode_ref(mask_words, values, row_offsets, cols):
    """Decode a bitmap-encoded sparse matrix to dense.

    Args:
      mask_words: uint32[k, words_per_row] packed little-endian bitmaps
        (bit t of word w covers column 32*w + t).
      values: f32[nnz_padded] compact nonzero values, row-major; entries
        beyond a row's nnz are ignored.
      row_offsets: int32[k] start offset of each row's values.
      cols: static number of columns.

    Returns: f32[k, cols] dense matrix.
    """
    k, wpr = mask_words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (mask_words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    bits = bits.reshape(k, wpr * 32)[:, :cols].astype(jnp.int32)
    # Per-row value index = exclusive prefix sum of bits.
    idx_in_row = jnp.cumsum(bits, axis=1) - bits
    idx = row_offsets[:, None] + idx_in_row
    gathered = values[jnp.clip(idx, 0, values.shape[0] - 1)]
    return jnp.where(bits == 1, gathered, 0.0)


def bitmap_matmul_ref(x, mask_words, values, row_offsets, cols):
    """y = x @ decode(bitmap)  — the sparse base-weight product."""
    w = bitmap_decode_ref(mask_words, values, row_offsets, cols)
    return x @ w


def fused_adapter_ref(x, a_cat, b_cat):
    """Concatenated multi-adapter update: (x @ A_cat) @ B_cat.

    Equivalent to sum_i (x @ A_i) @ B_i when A_cat/B_cat stack the
    adapters along the rank dimension (paper, adapter concatenation).
    """
    return (x @ a_cat) @ b_cat


def salr_linear_ref(x, w_hat, a_cat, b_cat):
    """Full SALR linear: sparse base + fused adapters.

    ``w_hat`` is the (dense-materialized) pruned base weight; on the rust
    serving path it stays bitmap-encoded and is decoded block-wise.
    """
    return x @ w_hat + fused_adapter_ref(x, a_cat, b_cat)


def nf4_dequant_ref(codes, scales, rows, cols, block):
    """Dequantize packed NF4 codes.

    Args:
      codes: uint8[ceil(rows*cols/2)] two 4-bit codes per byte (low first).
      scales: f32[ceil(rows*cols/block)] per-block absmax scales.
      rows, cols, block: static ints.
    """
    n = rows * cols
    lo = (codes & 0x0F).astype(jnp.int32)
    hi = (codes >> 4).astype(jnp.int32)
    idx = jnp.stack([lo, hi], axis=1).reshape(-1)[:n]
    vals = NF4_CODEBOOK[idx]
    scale_per_elem = scales[jnp.arange(n) // block]
    return (vals * scale_per_elem).reshape(rows, cols)


def nf4_matmul_ref(x, codes, scales, rows, cols, block):
    """y = x @ dequant(codes)."""
    return x @ nf4_dequant_ref(codes, scales, rows, cols, block)
