"""Model configurations shared between the JAX build path and (via the
artifact manifest) the rust coordinator.

Python is build-time only: these configs exist to shape the AOT-lowered
HLO executables. The rust side reads everything it needs from
``artifacts/manifest.json``; it never imports this module.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-only transformer with SALR-adapted linear layers."""

    name: str = "tiny"
    vocab_size: int = 256  # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    max_seq_len: int = 64
    # LoRA adapter rank and scaling (alpha / rank).
    rank: int = 8
    lora_alpha: float = 16.0
    # Sparsity-preservation residual adapter rank (Theorem 3's r).
    residual_rank: int = 16
    # Train-step batch shape (fixed at lowering time).
    batch_size: int = 16
    # SparseLoRA-style contextual sparsity: fraction of input channels kept.
    ctx_keep: float = 0.5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def lora_scaling(self) -> float:
        return self.lora_alpha / self.rank

    def adapted_layers(self):
        """Names of the linear layers that receive SALR treatment, in
        canonical order. Mirrored by rust's ``model::params``."""
        names = []
        for layer in range(self.n_layers):
            for lin in ("wq", "wk", "wv", "wo", "w_in", "w_out"):
                names.append(f"layer{layer}.{lin}")
        return names

    def linear_shape(self, lin: str):
        """(d_in, d_out) of an adapted linear by suffix name."""
        if lin in ("wq", "wk", "wv", "wo"):
            return (self.d_model, self.d_model)
        if lin == "w_in":
            return (self.d_model, self.d_ff)
        if lin == "w_out":
            return (self.d_ff, self.d_model)
        raise ValueError(f"unknown linear {lin}")

    def param_count(self) -> int:
        n = 2 * self.vocab_size * self.d_model  # embedding + lm head
        n += self.max_seq_len * self.d_model  # learned positions
        n += self.n_layers * (
            4 * self.d_model * self.d_model
            + 2 * self.d_model * self.d_ff
            + 2 * self.d_model  # two rmsnorm gains
        )
        n += self.d_model  # final norm
        return n

    def to_dict(self):
        return asdict(self)


# Named configurations. "tiny" drives the unit tests and the table
# experiments (fast enough to fine-tune many variants); "small" is the
# end-to-end example model; "bench" stretches the serving benchmarks.
CONFIGS = {
    "tiny": ModelConfig(),
    "small": ModelConfig(
        name="small",
        d_model=256,
        n_layers=4,
        n_heads=8,
        d_ff=1024,
        max_seq_len=128,
        rank=16,
        residual_rank=32,
        batch_size=8,
    ),
}


def get_config(name: str) -> ModelConfig:
    return CONFIGS[name]
